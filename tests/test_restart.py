"""Crash-safe serving: checksummed checkpoints, engine snapshot/restore
with exact-replay parity, the write-ahead request journal, and the
restart-tier chaos injectors.

Contracts under test (docs/DESIGN_robustness.md §6):
  * checkpoint generations verify per-leaf CRC32 + manifest schema on
    load; ANY mismatch (bit-rot, stale schema, torn tmp) falls back
    WARNED to the previous retained generation — corrupt state never
    loads silently, and only ``CheckpointError`` when nothing verifies;
  * ``ServeEngine.snapshot()/restore()`` round-trips the full engine
    (paged KV planes in all three kv_modes, slots, queue, results,
    counters) and the resumed run is token-for-token — and FF-logprob
    bit-for-bit — identical to an uninterrupted engine run;
  * wall-clock ``deadline_s`` budgets that expire across restart
    downtime retire as the documented ``TIMEOUT`` (never silently
    revived); deterministic ``deadline_steps`` budgets are unaffected;
  * the fsync'd write-ahead journal replays crash-lost submissions in
    original order and truncates once every journaled uid retires.

Local ``np.random.default_rng`` fixtures (not the session rng): restart
scenarios are order-sensitive, and a shared stream would couple them to
unrelated tests.
"""

import json
import os
import time
import warnings

import numpy as np
import pytest
import jax

from repro.chaos.inject import ChaosMonkey
from repro.checkpoint import (AsyncCheckpointer, CheckpointCorruptionWarning,
                              CheckpointError, available_steps, latest_step,
                              load_dict, save)
from repro.models import init_params
from repro.models.config import ModelConfig
from repro.serve import (OK, TIMEOUT, Request, ServeEngine, SNAPSHOT_SCHEMA,
                         resume_engine)

CFG = ModelConfig(name="restart-test", family="dense", num_layers=2,
                  d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                  vocab_size=256, max_seq_len=64, compute_dtype="float32",
                  remat=False)


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, jax.random.PRNGKey(0))


def _requests(rng, n=3, max_new=6, **kw):
    lens = rng.integers(5, 14, size=n)
    return [Request(uid=i,
                    prompt=rng.integers(1, CFG.vocab_size,
                                        size=int(l)).astype(np.int32),
                    max_new=max_new, **kw)
            for i, l in enumerate(lens)]


def _engine(params, kv_mode="bf16", **kw):
    return ServeEngine(params, CFG, max_batch=2, page_size=4, max_ctx=32,
                      kv_mode=kv_mode, **kw)


# --------------------------------------------------------------------------
# hardened checkpoint format: CRC32 + schema + fallback ladder
# --------------------------------------------------------------------------

def _write_gens(d, steps=(1, 2, 3)):
    rng = np.random.default_rng(781)
    trees = {}
    for s in steps:
        trees[s] = {"w": rng.standard_normal(16).astype(np.float32),
                    "ids": np.arange(s * 4, dtype=np.int32)}
        save(str(d), s, trees[s], extra={"tag": s})
    return trees


def test_checkpoint_roundtrip_with_extra(tmp_path):
    trees = _write_gens(tmp_path)
    arrays, step, extra = load_dict(str(tmp_path))
    assert step == 3 and extra["tag"] == 3
    for k in trees[3]:
        np.testing.assert_array_equal(arrays[k], trees[3][k])


def test_crc_bit_flip_falls_back_warned(tmp_path):
    """One flipped payload bit in the newest generation: the CRC verify
    must catch it and fall back — warned — to the previous generation."""
    trees = _write_gens(tmp_path)
    ChaosMonkey(7).flip_checkpoint_bit(str(tmp_path))
    with pytest.warns(CheckpointCorruptionWarning):
        arrays, step, extra = load_dict(str(tmp_path))
    assert step == 2 and extra["tag"] == 2
    for k in trees[2]:
        np.testing.assert_array_equal(arrays[k], trees[2][k])


def test_stale_manifest_schema_falls_back_warned(tmp_path):
    _write_gens(tmp_path)
    ChaosMonkey(8).stale_manifest(str(tmp_path), version=1)
    with pytest.warns(CheckpointCorruptionWarning):
        _, step, _ = load_dict(str(tmp_path))
    assert step == 2


def test_torn_tmp_skipped_and_garbage_collected(tmp_path):
    """A crash mid-save leaves ``step_XXXXXXXX.tmp`` behind; the read
    path must never surface it as a generation AND must remove it
    (regression: a .tmp matching the step glob once shadowed real
    generations)."""
    _write_gens(tmp_path)
    torn = ChaosMonkey(9).tear_checkpoint_tmp(str(tmp_path), step=99)
    assert available_steps(str(tmp_path)) == [1, 2, 3]
    assert not os.path.exists(torn)
    assert latest_step(str(tmp_path)) == 3


def test_every_generation_corrupt_raises(tmp_path):
    _write_gens(tmp_path)
    mk = ChaosMonkey(10)
    for s in (1, 2, 3):
        mk.flip_checkpoint_bit(str(tmp_path), step=s)
    with pytest.warns(CheckpointCorruptionWarning):
        with pytest.raises(CheckpointError):
            load_dict(str(tmp_path))


def test_missing_directory_raises_file_not_found(tmp_path):
    with pytest.raises(FileNotFoundError):
        load_dict(str(tmp_path / "nope"))


def test_async_checkpointer_poll_surfaces_write_error(tmp_path):
    """A failing disk must surface through poll() — not vanish in the
    writer thread (the engine turns it into an FFGuardWarning)."""
    ac = AsyncCheckpointer(str(tmp_path))
    # a plain FILE where save() needs its tmp directory: the writer
    # thread's rmtree/makedirs fails, not the caller
    (tmp_path / "step_00000001.tmp").write_text("in the way")
    ac.save(1, {"a": np.zeros(4, np.float32)})
    err = None
    for _ in range(500):
        err = ac.poll()
        if err is not None:
            break
        time.sleep(0.01)
    assert err is not None


def test_async_checkpointer_writes_verifiable_generation(tmp_path):
    ac = AsyncCheckpointer(str(tmp_path))
    tree = {"a": np.arange(6, dtype=np.float32)}
    ac.save(5, tree, extra={"k": 1})
    ac.wait()
    arrays, step, extra = load_dict(str(tmp_path))
    assert step == 5 and extra["k"] == 1
    np.testing.assert_array_equal(arrays["a"], tree["a"])


# --------------------------------------------------------------------------
# atomic tuning sidecar save
# --------------------------------------------------------------------------

def test_tuning_save_atomic(tmp_path):
    """ff.tuning.save writes via tmp+rename: the target parses as JSON
    and no ``.tmp`` residue survives."""
    from repro.ff import tuning
    path = str(tmp_path / "FF_TUNE.json")
    out = tuning.save(path)
    assert out == path and os.path.exists(path)
    assert not os.path.exists(path + ".tmp")
    with open(path) as f:
        payload = json.load(f)
    assert "meta" in payload and "table" in payload


# --------------------------------------------------------------------------
# engine snapshot/restore: exact-replay parity
# --------------------------------------------------------------------------

@pytest.mark.parametrize("kv_mode", ["bf16", "f32", "ff_bf16"])
def test_snapshot_restore_exact_replay(params, kv_mode):
    """Interrupt after 3 decode steps, restore into a fresh engine, run
    to completion: tokens identical and FF logprob limb pairs
    bit-for-bit vs an uninterrupted run of the same engine class (greedy
    decode is deterministic; same process, same compiled programs)."""
    rng = np.random.default_rng(782)
    reqs = _requests(rng)
    base = _engine(params, kv_mode)
    for r in reqs:
        base.submit(r)
    baseline = base.run()

    src = _engine(params, kv_mode)
    for r in reqs:
        src.submit(r)
    for _ in range(3):
        src.step()
    arrays, meta = src.snapshot()
    assert meta["schema"] == SNAPSHOT_SCHEMA

    dst = _engine(params, kv_mode)
    dst.restore(arrays, meta, downtime_s=0.0)
    resumed = dst.run()

    assert set(resumed) == set(baseline)
    for uid in baseline:
        assert resumed[uid].status == OK, resumed[uid].detail
        assert np.array_equal(resumed[uid].tokens, baseline[uid].tokens)
        assert np.array_equal(resumed[uid].logprobs_ff,
                              baseline[uid].logprobs_ff), \
            f"uid {uid}: FF limbs not bit-identical after restore"


def test_disk_roundtrip_resume_engine(params, tmp_path):
    """save_snapshot -> resume_engine round-trips through the verified
    on-disk format (CRC'd leaves + manifest) with the journal attached,
    and the journal is empty after every request retires cleanly."""
    rng = np.random.default_rng(783)
    reqs = _requests(rng)
    base = _engine(params)
    for r in reqs:
        base.submit(r)
    baseline = base.run()

    wal = str(tmp_path / "wal.jsonl")
    snap = str(tmp_path / "snap")
    src = _engine(params, journal=wal)
    for r in reqs:
        src.submit(r)
    for _ in range(3):
        src.step()
    src.save_snapshot(snap)
    del src

    eng = resume_engine(params, CFG, snap, journal=wal, max_batch=2,
                        page_size=4, max_ctx=32)
    resumed = eng.run()
    for uid in baseline:
        assert resumed[uid].status == OK
        assert np.array_equal(resumed[uid].tokens, baseline[uid].tokens)
        assert np.array_equal(resumed[uid].logprobs_ff,
                              baseline[uid].logprobs_ff)
    assert os.path.getsize(wal) == 0, "journal must truncate once clean"


def test_restore_rejects_schema_and_fingerprint_mismatch(params):
    rng = np.random.default_rng(784)
    reqs = _requests(rng, n=2)
    src = _engine(params)
    for r in reqs:
        src.submit(r)
    src.step()
    arrays, meta = src.snapshot()

    bad_schema = dict(meta, schema=SNAPSHOT_SCHEMA + 1)
    with pytest.raises(ValueError, match="schema"):
        _engine(params).restore(arrays, bad_schema)

    with pytest.raises(ValueError, match="kv_mode"):
        _engine(params, kv_mode="f32").restore(arrays, meta)

    busy = _engine(params)
    busy.submit(reqs[0])
    with pytest.raises(RuntimeError, match="freshly constructed"):
        busy.restore(arrays, meta)


def test_guard_state_survives_restore(params):
    """guard_stats counters ride the snapshot, and a guard-mode mismatch
    between snapshot and engine fails loudly instead of silently
    changing the degradation policy mid-flight."""
    rng = np.random.default_rng(785)
    reqs = _requests(rng, n=2)
    src = _engine(params, guard="check")
    for r in reqs:
        src.submit(r)
    for _ in range(2):
        src.step()
    src.guard_stats["flagged_rows"] += 3      # pretend probes fired
    arrays, meta = src.snapshot()

    with pytest.raises(ValueError, match="guard"):
        _engine(params, guard="off").restore(arrays, meta)

    dst = _engine(params, guard="check")
    dst.restore(arrays, meta, downtime_s=0.0)
    assert dst.guard_stats["flagged_rows"] == 3
    resumed = dst.run()
    assert all(r.status == OK for r in resumed.values())


def test_guard_stats_resume_through_obs_counters(params):
    """guard_stats is a view over the engine's obs
    ``serve_guard_events_total{kind=...}`` counters; restore() must seed
    those counters with the snapshot values so the restored engine's
    metrics RESUME (post-restore increments land on top of pre-crash
    counts, not on zero)."""
    rng = np.random.default_rng(789)
    reqs = _requests(rng, n=2)
    src = _engine(params, guard="check")
    for r in reqs:
        src.submit(r)
    src.step()
    src.guard_stats["flagged_rows"] += 3
    src.guard_stats["preempted"] += 1
    arrays, meta = src.snapshot()

    dst = _engine(params, guard="check")
    dst.restore(arrays, meta, downtime_s=0.0)
    snap = dst.obs.snapshot()["counters"]
    assert snap['serve_guard_events_total{kind="flagged_rows"}'] == 3
    assert snap['serve_guard_events_total{kind="preempted"}'] == 1
    # post-restore events accumulate ON TOP of the restored values
    dst.guard_stats["flagged_rows"] += 2
    after = dst.obs.snapshot()["counters"]
    assert after['serve_guard_events_total{kind="flagged_rows"}'] == 5
    assert dst.guard_stats["flagged_rows"] == 5
    # and a second snapshot round-trip carries the merged totals forward
    arrays2, meta2 = dst.snapshot()
    assert meta2["guard_stats"]["flagged_rows"] == 5


# --------------------------------------------------------------------------
# deadlines across restart downtime
# --------------------------------------------------------------------------

def test_wall_clock_deadline_expires_across_downtime(params):
    """A running request whose ``deadline_s`` elapsed while the process
    was down retires as TIMEOUT at restore — documented, never silently
    revived — while the deadline-free request completes untouched."""
    rng = np.random.default_rng(786)
    prompts = [rng.integers(1, CFG.vocab_size, size=n).astype(np.int32)
               for n in (6, 9)]
    reqs = [Request(uid=0, prompt=prompts[0], max_new=6, deadline_s=30.0),
            Request(uid=1, prompt=prompts[1], max_new=6)]
    src = _engine(params)
    for r in reqs:
        src.submit(r)
    for _ in range(3):
        src.step()
    arrays, meta = src.snapshot()

    dst = _engine(params)
    dst.restore(arrays, meta, downtime_s=120.0)
    assert dst.results[0].status == TIMEOUT
    assert "downtime" in dst.results[0].detail
    assert 0 < len(dst.results[0].tokens) < 6   # partial output kept
    res = dst.run()
    assert res[1].status == OK and len(res[1].tokens) == 6


def test_step_deadline_unaffected_by_downtime(params):
    """Deterministic ``deadline_steps`` budgets count decode steps, not
    wall clock: a huge downtime must not expire them."""
    rng = np.random.default_rng(787)
    reqs = _requests(rng, n=2, deadline_steps=64)
    src = _engine(params)
    for r in reqs:
        src.submit(r)
    for _ in range(3):
        src.step()
    arrays, meta = src.snapshot()

    dst = _engine(params)
    dst.restore(arrays, meta, downtime_s=3600.0)
    res = dst.run()
    assert all(r.status == OK for r in res.values())
    assert all(len(r.tokens) == 6 for r in res.values())


# --------------------------------------------------------------------------
# write-ahead request journal
# --------------------------------------------------------------------------

def test_journal_replays_crash_lost_submissions_in_order(params, tmp_path):
    """Submissions journaled but never snapshotted (crash before any
    checkpoint) are re-admitted in original order on resume and produce
    the same tokens as an uninterrupted run."""
    rng = np.random.default_rng(788)
    reqs = _requests(rng)
    base = _engine(params)
    for r in reqs:
        base.submit(r)
    baseline = base.run()

    wal = str(tmp_path / "wal.jsonl")
    crashed = _engine(params, journal=wal)
    for r in reqs:
        crashed.submit(r)
    del crashed                       # SIGKILL stand-in: no snapshot ever

    eng = resume_engine(params, CFG, str(tmp_path / "no-snap"), journal=wal,
                        max_batch=2, page_size=4, max_ctx=32)
    assert [q["req"].uid for q in eng.queue] == [r.uid for r in reqs]
    resumed = eng.run()
    for uid in baseline:
        assert resumed[uid].status == OK
        assert np.array_equal(resumed[uid].tokens, baseline[uid].tokens)
    assert os.path.getsize(wal) == 0


def test_journal_skips_torn_tail_line(params, tmp_path):
    """SIGKILL mid-append leaves a torn final JSONL line; recovery must
    warn, drop it, and replay every complete record."""
    from repro.serve import JournalWarning
    rng = np.random.default_rng(789)
    reqs = _requests(rng, n=2)
    wal = str(tmp_path / "wal.jsonl")
    crashed = _engine(params, journal=wal)
    for r in reqs:
        crashed.submit(r)
    del crashed
    with open(wal, "a") as f:
        f.write('{"op": "submit", "uid": 9, "prom')     # torn mid-record
    with pytest.warns(JournalWarning):
        eng = resume_engine(params, CFG, str(tmp_path / "no-snap"),
                            journal=wal, max_batch=2, page_size=4,
                            max_ctx=32)
    assert [q["req"].uid for q in eng.queue] == [0, 1]
    res = eng.run()
    assert sorted(res) == [0, 1]
