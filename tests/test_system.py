"""End-to-end behaviour tests for the paper's system: the float-float
precision policy driving a full train->checkpoint->serve cycle."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core.policy import PrecisionPolicy
from repro.core.selfcheck import check_eft_safe
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import init_params, init_cache, prefill, decode_step
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamW
from repro.train.train_step import make_train_step


def test_system_train_then_serve(tmp_path):
    """Full cycle: EFT-safe toolchain -> FF-policy training descends ->
    checkpoint -> restore -> serve greedily from the trained weights."""
    assert check_eft_safe()

    cfg = ModelConfig(
        name="sys", family="dense", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=2, d_ff=256, vocab_size=256, head_dim=32,
        max_seq_len=128, attn_block_q=32, attn_block_kv=32, loss_chunk=32,
        compute_dtype="float32", remat=False)
    policy = PrecisionPolicy.make("ff_reduce", compute_dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = AdamW(learning_rate=3e-3, ff=True)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg, policy, opt))
    data = SyntheticLM(DataConfig(vocab_size=256, seq_len=64, global_batch=8))

    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses  # actually learns

    # checkpoint round-trip
    from repro.checkpoint import checkpoint as ckpt
    ckpt.save(str(tmp_path), 30, {"params": params})
    restored, _, _ = ckpt.load(str(tmp_path), {"params": params})
    params = jax.tree_util.tree_map(jnp.asarray, restored["params"])

    # serve from trained weights
    B, S = 2, 16
    prompt = jnp.asarray(data.batch(99)["tokens"][:B, :S])
    cache = init_cache(cfg, B, 64, dtype=jnp.float32)
    logits, cache = jax.jit(lambda p, b, c: prefill(p, b, cfg, c, policy))(
        params, {"tokens": prompt}, cache)
    assert bool(jnp.all(jnp.isfinite(logits)))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, _ = jax.jit(
        lambda p, t, c: decode_step(p, t, jnp.int32(S), c, cfg, policy))(
        params, tok, cache)
    assert bool(jnp.all(jnp.isfinite(logits2)))
