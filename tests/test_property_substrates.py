"""Property-based (hypothesis) invariants for the substrates (data pipeline
determinism, gradient compression, FF master-weight integration).

Split out of test_substrates.py so the main suite runs without hypothesis;
this module skips itself when the dependency is absent.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")

from repro.data.pipeline import DataConfig, SyntheticLM


# ---------------------------------------------------------------------------
# property-based invariants (hypothesis)
# ---------------------------------------------------------------------------

from hypothesis import given, settings, strategies as st


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(0, 3), st.integers(1, 4))
def test_prop_pipeline_determinism(index, seed, hosts):
    """batch(i) is a pure function of (seed, host, i); host shards disjoint."""
    cfg = DataConfig(vocab_size=97, seq_len=16, global_batch=4 * hosts,
                     seed=seed)
    feeds = [SyntheticLM(cfg, host_id=h, num_hosts=hosts) for h in range(hosts)]
    again = [SyntheticLM(cfg, host_id=h, num_hosts=hosts) for h in range(hosts)]
    for a, b in zip(feeds, again):
        x, y = a.batch(index), b.batch(index)
        assert np.array_equal(x["tokens"], y["tokens"])
        assert np.array_equal(x["targets"], y["targets"])
        assert x["tokens"].shape == (4, 16)
        assert x["tokens"].min() >= 0 and x["tokens"].max() < 97


@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(-1e3, 1e3, allow_nan=False, width=32),
                min_size=1, max_size=64))
def test_prop_compression_error_bounded(vals):
    """Error-feedback invariant: after compressing any gradient once, the
    carried residual is <= one quantization step."""
    from repro.optim.compress import init_feedback, compress
    g = {"w": jnp.asarray(np.asarray(vals, np.float32))}
    q, scales, state = compress(g, init_feedback(g))
    resid = np.abs(np.asarray(state.err_hi["w"], np.float64)
                   + np.asarray(state.err_lo["w"], np.float64))
    step = float(scales["w"])
    assert resid.max() <= step * 0.5 + 1e-12


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 5), st.integers(1, 3))
def test_prop_ff_master_exact_integration(n_steps_pow, scale_pow):
    """FF master weights integrate ANY sequence of sub-ulp deltas exactly
    (up to 2^-44 of the weight) — the core paper guarantee, propertyized."""
    from repro.optim.adamw import AdamW
    n = 10 ** n_steps_pow // 10
    lr = 10.0 ** (-6 - scale_pow)
    opt = AdamW(learning_rate=lr, b1=0.0, b2=0.0, eps=1e-30,
                weight_decay=0.0, ff=True)
    p = {"w": jnp.ones((8,), jnp.float32)}
    s = opt.init(p)
    g = {"w": jnp.ones((8,), jnp.float32)}
    step = jax.jit(lambda p_, s_: opt.update(g, s_, p_))
    for _ in range(n):
        p, s = step(p, s)
    total = (np.asarray(p["w"], np.float64)
             + np.asarray(s.master_lo["w"], np.float64))
    expect = 1.0 - lr * n
    # per-step Add22 rounding ~2^-48 relative accumulates linearly in n
    bound = max(abs(expect), 1.0) * (2.0**-40 + n * 2.0**-48)
    assert np.abs(total - expect).max() < bound
