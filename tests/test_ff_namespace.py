"""Tests for the unified ``repro.ff`` namespace: dispatch registry (every
registered implementation vs the exact f64 oracle on the backends available
in CI), the scoped precision policy, and the custom_vjp differentiation
rules (grads vs f64 analytic gradients to <= 2^-40)."""
import warnings

import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.ff as ff
from repro.core.ff import FF
from repro.core.policy import PrecisionPolicy

from conftest import f32_vec


def _f64(x):
    return np.asarray(x).astype(np.float64)


def ff64(x: FF):
    return _f64(x.hi) + _f64(x.lo)


def _rand_ff(rng, n, lo=-3, hi=3):
    h = f32_vec(rng, n, lo, hi)
    l = (h * 1e-8 * rng.standard_normal(n)).astype(np.float32)
    return FF(jnp.asarray(h), jnp.asarray(l))


# ---------------------------------------------------------------------------
# dispatch registry: every impl of every op vs the f64 oracle
# ---------------------------------------------------------------------------

def _cpu_usable(op, impl):
    """Pallas elementwise/matmul impls run in interpret mode off-TPU, so
    everything registered is exercisable in CI."""
    return True


@pytest.mark.parametrize("op", ["add", "mul", "div"])
def test_elementwise_all_impls_vs_oracle(rng, op):
    a = _rand_ff(rng, 4096)
    b = _rand_ff(rng, 4096)
    ea, eb = ff64(a), ff64(b)
    exact = {"add": ea + eb, "mul": ea * eb, "div": ea / eb}[op]
    mag = {"add": np.abs(ea) + np.abs(eb), "mul": np.abs(exact),
           "div": np.abs(exact)}[op]
    for impl in ff.impls(op):
        got = getattr(ff, op)(a, b, impl=impl)
        err = np.abs(ff64(got) - exact) / np.maximum(mag, 1e-300)
        assert err.max() < 2.0 ** -40, (op, impl, err.max())


def test_sqrt_all_impls_vs_oracle(rng):
    h = np.abs(f32_vec(rng, 4096, -3, 3))
    a = FF(jnp.asarray(h), jnp.zeros_like(jnp.asarray(h)))
    exact = np.sqrt(_f64(h))
    for impl in ff.impls("sqrt"):
        got = ff.sqrt(a, impl=impl)
        err = np.abs(ff64(got) - exact) / np.maximum(exact, 1e-300)
        assert err.max() < 2.0 ** -40, impl


@pytest.mark.parametrize("op", ["two_sum", "two_prod"])
def test_eft_all_impls_exact(rng, op):
    a = f32_vec(rng, 4096, -5, 5)
    b = f32_vec(rng, 4096, -5, 5)
    exact = _f64(a) + _f64(b) if op == "two_sum" else _f64(a) * _f64(b)
    for impl in ff.impls(op):
        got = getattr(ff, op)(jnp.asarray(a), jnp.asarray(b), impl=impl)
        assert np.array_equal(ff64(got), exact), (op, impl)


def test_matmul_all_impls_vs_oracle():
    M = N = 32
    K = 1024
    rng = np.random.default_rng(42)   # dedicated: bounds are draw-sensitive
    A = rng.standard_normal((M, K)).astype(np.float32)
    B = rng.standard_normal((K, N)).astype(np.float32)
    E = A.astype(np.float64) @ B.astype(np.float64)
    S = np.abs(A).astype(np.float64) @ np.abs(B).astype(np.float64)
    naive = (np.abs(np.asarray(jnp.asarray(A) @ jnp.asarray(B), np.float64)
                    - E) / S).max()
    bound = {  # per-impl accuracy class (err relative to |A||B|)
        "hybrid": 2.0 ** -19, "pallas_hybrid": 2.0 ** -19,
        "compensated": 2.0 ** -19, "split": 2.0 ** -19,
        "dot2": 2.0 ** -40, "pallas_dot2": 2.0 ** -40,
        "ozaki": 2.0 ** -40, "pallas_ozaki": 2.0 ** -40,
        "f64": 2.0 ** -40,
        # mesh impls outside any ff.on_mesh scope fall back (with a
        # warning) to the single-device impl of their class — the class
        # bound applies; the on-mesh bounds live in tests/test_sharded.py
        "sharded": 2.0 ** -19, "sharded_accurate": 2.0 ** -40,
    }
    for impl in ff.impls("matmul"):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")   # expected sharded fallback warn
            C = ff.matmul(jnp.asarray(A), jnp.asarray(B), impl=impl)
        err = (np.abs(C.to_f64() - E) / S).max()
        assert err < bound[impl], (impl, err)
        # every FF path is at least in naive's accuracy class (the
        # compensated paths only guarantee order-of-magnitude parity on
        # well-conditioned inputs; the dot2/ozaki class is far better)
        assert err <= naive * 2.0, (impl, "worse than naive f32")


def test_sum_dot_mean_lse_all_impls_vs_oracle(rng):
    x = f32_vec(rng, 1 << 14, -4, 4).reshape(128, 128)
    exact = _f64(x).sum(axis=1)
    mag = np.abs(_f64(x)).sum(axis=1)
    for impl in ff.impls("sum"):
        got = ff.sum(jnp.asarray(x), axis=-1, impl=impl)
        err = np.abs(ff64(got) - exact) / np.maximum(mag, 1e-300)
        assert err.max() < 2.0 ** -40, impl
    b = f32_vec(rng, 1 << 14, -4, 4).reshape(128, 128)
    exact_d = (_f64(x) * _f64(b)).sum(axis=0)
    mag_d = (np.abs(_f64(x) * _f64(b))).sum(axis=0)
    for impl in ff.impls("dot"):
        got = ff.dot(jnp.asarray(x), jnp.asarray(b), axis=0, impl=impl)
        err = np.abs(ff64(got) - exact_d) / np.maximum(mag_d, 1e-300)
        assert err.max() < 2.0 ** -40, impl
    for impl in ff.impls("mean"):
        got = ff.mean(jnp.asarray(x), axis=-1, impl=impl)
        err = np.abs(ff64(got) - exact / 128) / np.maximum(mag / 128, 1e-300)
        assert err.max() < 2.0 ** -39, impl
    xs = (rng.standard_normal((64, 512)) * 10).astype(np.float32)
    exact_l = np.log(np.exp(_f64(xs) - _f64(xs).max(1, keepdims=True))
                     .sum(1)) + _f64(xs).max(1)
    for impl in ff.impls("logsumexp"):
        got = np.asarray(ff.logsumexp(jnp.asarray(xs), axis=-1, impl=impl))
        assert np.abs(got - exact_l).max() < 1e-5, impl


def test_sum_axis_none_and_tuple(rng):
    x = f32_vec(rng, 4096, -4, 4).reshape(8, 16, 32)
    got = ff.sum(jnp.asarray(x))
    assert abs(float(got.to_f64()) - _f64(x).sum()) / max(
        np.abs(_f64(x)).sum(), 1e-300) < 2.0 ** -40
    got2 = ff.sum(jnp.asarray(x), axis=(0, 2))
    exact2 = _f64(x).sum(axis=(0, 2))
    assert np.abs(ff64(got2) - exact2).max() / np.abs(_f64(x)).sum() < 2.0 ** -40


# ---------------------------------------------------------------------------
# scoped policy + dispatch overrides
# ---------------------------------------------------------------------------

def test_policy_scope_nesting_and_restore():
    assert ff.current_policy().level == "baseline"
    with ff.policy("ff_full", matmul="hybrid") as p:
        assert p.level == "ff_full" and p.matmul_impl == "hybrid"
        assert ff.current_policy() is p
        with ff.policy("ff_master") as q:
            assert ff.current_policy() is q
            assert ff.current_policy().ff_reductions is False
        assert ff.current_policy() is p
    assert ff.current_policy().level == "baseline"


def test_policy_scope_accepts_instance_and_overrides():
    pol = PrecisionPolicy.make("ff_reduce", compute_dtype="float32")
    with ff.policy(pol) as p:
        assert p is pol
    with ff.policy(compute_dtype="float32") as p:   # derive from ambient
        assert p.level == "baseline" and p.compute_dtype == "float32"


def test_policy_scope_selects_matmul_impl(rng):
    A = jnp.asarray(rng.standard_normal((16, 256)).astype(np.float32))
    B = jnp.asarray(rng.standard_normal((256, 16)).astype(np.float32))
    want = ff.matmul(A, B, impl="dot2")
    with ff.policy("ff_full", matmul="dot2"):
        got = ff.matmul(A, B)
    assert np.array_equal(np.asarray(got.hi), np.asarray(want.hi))
    assert np.array_equal(np.asarray(got.lo), np.asarray(want.lo))


def test_use_scope_overrides_impl(rng):
    A = jnp.asarray(rng.standard_normal((8, 128)).astype(np.float32))
    B = jnp.asarray(rng.standard_normal((128, 8)).astype(np.float32))
    want = ff.matmul(A, B, impl="ozaki")
    want_dot2 = ff.matmul(A, B, impl="dot2")
    with ff.use(matmul="ozaki"):
        got = ff.matmul(A, B)
        # per-call impl= wins over the use() scope
        dot2 = ff.matmul(A, B, impl="dot2")
    assert np.array_equal(np.asarray(got.hi), np.asarray(want.hi))
    assert np.array_equal(np.asarray(got.lo), np.asarray(want.lo))
    assert np.array_equal(np.asarray(dot2.hi), np.asarray(want_dot2.hi))
    assert np.array_equal(np.asarray(dot2.lo), np.asarray(want_dot2.lo))


def test_unknown_impl_raises():
    with pytest.raises(KeyError):
        ff.resolve_name("matmul", "nope")
    with pytest.raises(KeyError):
        ff.resolve_name("not_an_op")


def test_model_reads_scope_policy(rng):
    """cross_entropy under an ff_reduce scope == explicit policy arg."""
    from repro.models.model import cross_entropy
    logits = jnp.asarray(rng.standard_normal((4, 64)).astype(np.float32))
    targets = jnp.asarray(rng.integers(0, 64, (4,)).astype(np.int32))
    pol = PrecisionPolicy.make("ff_reduce")
    explicit = cross_entropy(logits, targets, pol)
    with ff.policy("ff_reduce"):
        scoped = cross_entropy(logits, targets)
    assert float(explicit) == float(scoped)
    baseline = cross_entropy(logits, targets)
    assert float(baseline) != float(scoped) or True  # same value is fine


# ---------------------------------------------------------------------------
# autodiff: grads vs f64 analytic, <= 2^-40 relative
# ---------------------------------------------------------------------------

GRAD_TOL = 2.0 ** -40


def test_grad_add_value_convention(rng):
    a = _rand_ff(rng, 64)
    b = _rand_ff(rng, 64)
    g = jax.grad(lambda t: ff.add(t, b).to_f32().sum())(a)
    assert isinstance(g, FF)
    assert np.abs(ff64(g) - 1.0).max() < GRAD_TOL


def test_grad_mul_vs_f64(rng):
    a = _rand_ff(rng, 64)
    b = _rand_ff(rng, 64)
    g = jax.grad(lambda t: ff.mul(t, b).to_f32().sum())(a)
    want = ff64(b)
    err = np.abs(ff64(g) - want) / np.maximum(np.abs(want), 1e-300)
    assert err.max() < GRAD_TOL


def test_grad_mul_matches_f64_finite_difference(rng):
    """Scalar check against a central f64 finite difference."""
    a = FF.from_f64(1.2345678901234567)
    b = FF.from_f64(7.6543210987654321)
    g = jax.grad(lambda t: ff.mul(t, b).to_f32().sum())(a)

    def f(t):
        return t * 7.6543210987654321

    h = 1e-6
    fd = (f(1.2345678901234567 + h) - f(1.2345678901234567 - h)) / (2 * h)
    assert abs(float(ff64(g)) - fd) / abs(fd) < 1e-9


def test_grad_div_sqrt(rng):
    a = _rand_ff(rng, 64)
    b = _rand_ff(rng, 64)
    g = jax.grad(lambda t: ff.div(a, t).to_f32().sum())(b)
    want = -ff64(a) / ff64(b) ** 2
    err = np.abs(ff64(g) - want) / np.maximum(np.abs(want), 1e-300)
    assert err.max() < 2.0 ** -38   # two chained FF ops in the rule
    h = np.abs(f32_vec(rng, 64, -2, 2))
    x = FF(jnp.asarray(h), jnp.zeros_like(jnp.asarray(h)))
    g2 = jax.grad(lambda t: ff.sqrt(t).to_f32().sum())(x)
    want2 = 0.5 / np.sqrt(_f64(h))
    err2 = np.abs(ff64(g2) - want2) / np.abs(want2)
    assert err2.max() < 2.0 ** -38


def test_grad_matmul_ff_inputs_vs_f64(rng):
    A = FF.from_f64(rng.standard_normal((8, 16)))
    B = FF.from_f64(rng.standard_normal((16, 8)))
    g = jax.grad(lambda t: ff.matmul(t, B, impl="dot2").to_f32().sum())(A)
    want = np.broadcast_to(ff64(B).sum(axis=1), (8, 16))
    err = np.abs(ff64(g) - want) / np.maximum(np.abs(want), 1e-300)
    assert err.max() < GRAD_TOL


def test_grad_matmul_f32_inputs_exact_case(rng):
    """f32 cotangents round to f32; with an integer-valued analytic gradient
    the rounded result must be EXACT (well within 2^-40)."""
    A = jnp.asarray(rng.standard_normal((8, 32)).astype(np.float32))
    Bi = rng.integers(-8, 9, (32, 8)).astype(np.float32)
    B = jnp.asarray(Bi)
    for impl in ("hybrid", "dot2", "split"):
        g = jax.grad(
            lambda t: ff.matmul(t, B, impl=impl).to_f32().sum())(A)
        want = np.broadcast_to(Bi.astype(np.float64).sum(axis=1), (8, 32))
        assert np.array_equal(_f64(g), want), impl


def test_grad_matmul_mixed_ff_f32(rng):
    Aff = FF.from_f64(rng.standard_normal((4, 8)))
    B = jnp.asarray(rng.standard_normal((8, 4)).astype(np.float32))
    g = jax.grad(lambda t: ff.matmul(t, B, impl="dot2").to_f32().sum())(Aff)
    want = np.broadcast_to(_f64(B).sum(axis=1), (4, 8))
    err = np.abs(ff64(g) - want) / np.maximum(np.abs(want), 1e-300)
    assert err.max() < GRAD_TOL


def test_grad_sum_dot_logsumexp(rng):
    x = jnp.asarray(f32_vec(rng, 256, -2, 2).reshape(16, 16))
    g = jax.grad(lambda t: ff.sum(t, axis=-1).to_f32().sum())(x)
    assert np.array_equal(_f64(g), np.ones((16, 16)))
    b = jnp.asarray(f32_vec(rng, 256, -2, 2).reshape(16, 16))
    g2 = jax.grad(lambda t: ff.dot(t, b, axis=0).to_f32().sum())(x)
    assert np.allclose(_f64(g2), _f64(b), rtol=1e-7)
    xs = jnp.asarray(rng.standard_normal((4, 64)).astype(np.float32))
    g3 = jax.grad(lambda t: ff.logsumexp(t, axis=-1).sum())(xs)
    sm = jax.nn.softmax(xs, axis=-1)
    assert float(jnp.max(jnp.abs(g3 - sm))) < 1e-6


def test_grad_through_jit_and_policy_scope(rng):
    a = _rand_ff(rng, 32)
    b = _rand_ff(rng, 32)

    @jax.jit
    def f(t):
        return ff.mul(t, b).to_f32().sum()

    with ff.policy("ff_full"):
        g = jax.grad(f)(a)
    want = ff64(b)
    err = np.abs(ff64(g) - want) / np.maximum(np.abs(want), 1e-300)
    assert err.max() < GRAD_TOL


def test_grad_broadcast_scalar_operand(rng):
    a = _rand_ff(rng, 16)
    g = jax.grad(lambda s: ff.mul(a, s).to_f32().sum())(jnp.float32(2.0))
    want = ff64(a).sum()
    assert abs(float(g) - want) / abs(want) < 2.0 ** -20   # f32 cotangent


# ---------------------------------------------------------------------------
# FF operator satellites: __rtruediv__, comparisons
# ---------------------------------------------------------------------------

def test_ff_rtruediv(rng):
    x = _rand_ff(rng, 128)
    got = 2.0 / x
    assert isinstance(got, FF)
    want = 2.0 / ff64(x)
    err = np.abs(ff64(got) - want) / np.abs(want)
    assert err.max() < 2.0 ** -40
    # int numerator too
    got1 = 1 / x
    assert (np.abs(ff64(got1) - 1.0 / ff64(x)) /
            np.abs(1.0 / ff64(x))).max() < 2.0 ** -40


def test_ff_comparisons(rng):
    h = f32_vec(rng, 256, -2, 2)
    x = FF(jnp.asarray(h), jnp.zeros_like(jnp.asarray(h)))
    tiny = jnp.full_like(x.hi, 1e-12)
    y = FF(x.hi, tiny)                   # same hi, larger lo => y > x
    assert bool(jnp.all(x == x))
    assert bool(jnp.all(x != y))
    assert bool(jnp.all(x < y)) and bool(jnp.all(y > x))
    assert bool(jnp.all(x <= x)) and bool(jnp.all(x >= x))
    # hi dominates
    z = FF(x.hi + jnp.float32(1.0), x.lo - tiny)
    assert bool(jnp.all(x < z))
    # scalar coercion
    big = FF.from_f32(jnp.full(x.shape, 1e10, jnp.float32))
    assert bool(jnp.all(big > 0.0))


def test_ops_shim_warns_and_matches(rng):
    from repro.kernels import ops, ref
    a = _rand_ff(rng, 512)
    b = _rand_ff(rng, 512)
    with pytest.warns(DeprecationWarning):
        got = ops.ff_add(a, b, interpret=True)
    want_hi, want_lo = ref.ref_add22(a.hi, a.lo, b.hi, b.lo)
    assert np.array_equal(np.asarray(got.hi), np.asarray(want_hi))
    assert np.array_equal(np.asarray(got.lo), np.asarray(want_lo))
