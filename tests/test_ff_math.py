"""``ff.math`` — the FF elementary-function library.

Per-function ulp ceilings against an f64 oracle across the argument-
reduction boundaries (multiples of ln2/2, branch seams, saturation
edges, negative zero, subnormals), gradient flow (<= 2^-40 vs f64),
dispatch/tuning integration, fusion both-executor bitwise parity for
transcendental chains, the accurate-class softmax/logsumexp impls, and
the model-policy migration (``ff_math`` switch: default bitwise, opt-in
routed).

Oracle note: numpy's f64 libm (and ``math.erf``) is <= 1 ulp_f64
(~2^-52) — two orders below every bound asserted here.  FF inputs are
sampled so BOTH limbs stay normal (the format itself cannot carry 44
bits once ``lo`` underflows; that boundary is documented in NUMERICS,
not a library defect).
"""

from __future__ import annotations

import math

import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.ff as ff
from repro.core import ffmath
from repro.core.ff import FF
from repro.ff import dispatch, fusion

RNG = np.random.default_rng(1234)

_ERF64 = np.vectorize(math.erf)


def _oracle(name):
    return {
        "exp": np.exp, "expm1": np.expm1, "log": np.log, "log1p": np.log1p,
        "tanh": np.tanh, "sigmoid": lambda t: 1.0 / (1.0 + np.exp(-t)),
        "erf": _ERF64,
        "gelu": lambda t: 0.5 * t * (1.0 + _ERF64(t / np.sqrt(2.0))),
        "silu": lambda t: t / (1.0 + np.exp(-t)),
    }[name]


def _ff_in(x64):
    x64 = np.asarray(x64, np.float64)
    hi = np.float32(x64)
    lo = np.float32(x64 - np.float64(hi))
    return FF(jnp.asarray(hi), jnp.asarray(lo)), np.float64(hi) + np.float64(lo)


def _rel_err(fn_name, x64, impl="jnp", **kw):
    a, xin = _ff_in(x64)
    out = getattr(ff, fn_name)(a, impl=impl, **kw)
    got = np.float64(np.asarray(out.hi)) + np.float64(np.asarray(out.lo))
    want = _oracle(fn_name)(xin)
    ok = np.isfinite(want)
    err = np.abs(got[ok] - want[ok]) / np.maximum(np.abs(want[ok]), 1e-300)
    return err.max() if err.size else 0.0


# ---------------------------------------------------------------------------
# accuracy contracts (documented in docs/NUMERICS.md)
# ---------------------------------------------------------------------------

# (fn, sampler, bound) — bound is the documented contract, asserted on the
# jnp impl (pallas is pinned bitwise-identical below, f64 is trivially
# tighter).  Reduced-domain rows carry the <= 2 ulp_FF (2^-43) acceptance
# bar; full-domain rows the documented reconstruction amplification.
N = 60000
CASES = [
    ("exp", lambda: RNG.uniform(-0.3465, 0.3465, N), 2.0**-43),
    ("exp", lambda: RNG.uniform(-55, 88, N), 2.0**-42),
    ("expm1", lambda: RNG.uniform(-0.3465, 0.3465, N), 2.0**-43),
    ("expm1", lambda: RNG.uniform(-20, 20, N), 2.0**-41),
    ("expm1", lambda: RNG.uniform(-1, 1, N) * 10.0 **
     RNG.uniform(-25, 0, N), 2.0**-43),
    ("log", lambda: RNG.uniform(0.70711, 1.41421, N), 2.0**-43),
    # inputs sampled with BOTH limbs normal (|x| in [2^-79, 2^80]): below
    # that the FF *input* cannot carry 44 bits (lo underflows) — the
    # format boundary documented in NUMERICS, not a log defect
    ("log", lambda: np.exp(RNG.uniform(-55, 55, N)), 2.0**-42),
    ("log1p", lambda: RNG.uniform(-0.29, 0.41, N), 2.0**-43),
    ("log1p", lambda: RNG.uniform(-1, 1, N) * 10.0 **
     RNG.uniform(-30, 0, N), 2.0**-43),
    ("log1p", lambda: np.exp(RNG.uniform(-30, 4, N)), 2.0**-43),
    ("tanh", lambda: RNG.uniform(-0.35, 0.35, N), 2.0**-43),
    ("tanh", lambda: RNG.uniform(-20, 20, N), 2.0**-41),
    ("sigmoid", lambda: RNG.uniform(-30, 30, N), 2.0**-42),
    ("erf", lambda: RNG.uniform(-1, 1, N), 2.0**-43),
    ("erf", lambda: RNG.uniform(-6, 6, N), 2.0**-42),
    ("gelu", lambda: RNG.uniform(-1, 20, N), 2.0**-42),
    ("silu", lambda: RNG.uniform(-30, 30, N), 2.0**-42),
]


@pytest.mark.parametrize("fn,sampler,bound",
                         CASES, ids=[f"{c[0]}-{i}" for i, c in enumerate(CASES)])
def test_accuracy_contract(fn, sampler, bound):
    err = _rel_err(fn, sampler())
    assert err <= bound, f"{fn}: 2^{np.log2(max(err, 1e-300)):.1f} > " \
                         f"2^{np.log2(bound):.1f}"


def test_gelu_negative_tail_absolute():
    """1 + erf cancels for x << 0: the contract there is ABSOLUTE 2^-40
    (documented; relative accuracy would need an FF erfc kernel)."""
    a, xin = _ff_in(RNG.uniform(-8, -1, 20000))
    out = ff.gelu(a, impl="jnp")
    got = np.float64(np.asarray(out.hi)) + np.float64(np.asarray(out.lo))
    want = _oracle("gelu")(xin)
    assert np.abs(got - want).max() <= 2.0**-40


def test_pow_contract():
    """pow error grows ~(1 + |b ln a|) 2^-43 (the double-word pow bound)."""
    a64 = np.exp(RNG.uniform(-3, 3, N))
    b64 = RNG.uniform(-8, 8, N)
    a, ain = _ff_in(a64)
    b, bin_ = _ff_in(b64)
    out = ff.pow(a, b, impl="jnp")
    got = np.float64(np.asarray(out.hi)) + np.float64(np.asarray(out.lo))
    want = ain ** bin_
    ok = np.isfinite(want) & (np.abs(want) > 1e-300)
    rel = np.abs(got[ok] - want[ok]) / np.abs(want[ok])
    budget = (1.0 + np.abs(bin_[ok] * np.log(ain[ok]))) * 2.0**-42
    assert (rel <= budget).all()


def test_reduction_boundaries():
    """Multiples of ln2/2 (the exp reduction seam), the log mantissa seam
    (sqrt2 neighborhood), and the tanh/erf branch cutoffs: contracts hold
    ON the seams, where Cody-Waite/branch bugs live."""
    ln2 = float(np.log(2.0))
    ks = np.arange(-100, 101)
    near = (ks[None, :] * (ln2 / 2)
            + np.linspace(-4e-7, 4e-7, 41)[:, None]).ravel()
    assert _rel_err("exp", near[np.abs(near) < 88]) <= 2.0**-43
    m = np.float64(np.float32(np.sqrt(2.0)))
    seam = m + np.linspace(-1e-6, 1e-6, 2001)
    assert _rel_err("log", seam) <= 2.0**-43
    for fn, cut in (("tanh", 0.35), ("erf", 1.0), ("erf", 4.0)):
        edge = cut + np.linspace(-1e-5, 1e-5, 2001)
        assert _rel_err(fn, np.concatenate([edge, -edge])) <= 2.0**-41


def test_saturation_and_special_values():
    sp = np.array([0.0, -0.0, np.inf, -np.inf, np.nan,
                   89.5, 200.0, -104.0, -1e30, 1e30], np.float32)
    x = FF(jnp.asarray(sp), jnp.zeros_like(jnp.asarray(sp)))

    def col(out):
        return np.asarray(out.hi)

    e = col(ff.exp(x, impl="jnp"))
    assert e[2] == np.inf and e[3] == 0 and np.isnan(e[4])
    assert e[5] == np.inf and e[6] == np.inf and e[7] == 0 and e[8] == 0
    t = col(ff.tanh(x, impl="jnp"))
    assert t[2] == 1 and t[3] == -1 and abs(t[9]) == 1 and np.isnan(t[4])
    r = col(ff.erf(x, impl="jnp"))
    assert r[2] == 1 and r[3] == -1 and r[9] == 1 and r[8] == -1
    lg = col(ff.log(x, impl="jnp"))
    assert lg[0] == -np.inf and lg[1] == -np.inf and lg[2] == np.inf
    assert np.isnan(lg[3]) and np.isnan(lg[8])
    s = col(ff.sigmoid(x, impl="jnp"))
    assert s[2] == 1 and s[3] == 0 and s[0] == 0.5 and s[1] == 0.5
    # pow edges (IEEE limits; a<0 -> nan by the documented domain rule)
    pa = FF(*map(jnp.asarray, (np.float32([0, 0, 0, 2, -2, np.inf, np.inf]),
                               np.zeros(7, np.float32))))
    pb = FF(*map(jnp.asarray, (np.float32([2, 0, -1, 10, 2, 2, -2]),
                               np.zeros(7, np.float32))))
    p = np.asarray(ff.pow(pa, pb, impl="jnp").hi)
    assert p[0] == 0 and p[1] == 1 and p[2] == np.inf and p[3] == 1024
    assert np.isnan(p[4]) and p[5] == np.inf and p[6] == 0
    # domain semantics must not flip between impl tiers (review finding:
    # the f64/fast nan masks used to fire before the b == 0 -> 1 rule)
    for impl in ("f64", "fast"):
        q = np.asarray(ff.pow(pa, pb, impl=impl).hi)
        assert q[1] == 1 and np.isnan(q[4]), impl
        neg0 = ff.pow(FF.from_f32(jnp.float32(-2.0)),
                      FF.from_f32(jnp.float32(0.0)), impl=impl)
        assert float(neg0.hi) == 1.0, impl


def test_exp_expm1_overflow_window_saturates_clean():
    """x in (~88.72, 89]: the hi limb overflows naturally before the clip
    bound — exp must return a clean (inf, 0) pair and expm1 must not turn
    inf - 1 into nan through the TwoSum residual (review finding)."""
    xs = np.float32([88.73, 88.8, 88.9, 89.0, 89.05])
    x = FF(jnp.asarray(xs), jnp.zeros_like(jnp.asarray(xs)))
    for fn in ("exp", "expm1"):
        out = getattr(ff, fn)(x, impl="jnp")
        assert (np.asarray(out.hi) == np.inf).all(), fn
        assert (np.asarray(out.lo) == 0).all(), fn


def test_moe_gate_honors_ff_math():
    """The expert SwiGLU gate and the shared-expert MLP take the same
    ff_math switch as the dense path (review finding)."""
    from repro.models import moe as moe_lib
    from repro.models.config import ModelConfig
    cfg = ModelConfig(name="t", family="moe", num_layers=1, d_model=32,
                      num_heads=4, num_kv_heads=4, d_ff=64, vocab_size=64,
                      moe_num_experts=4, moe_top_k=2, moe_d_ff=32,
                      moe_shared_experts=1)
    p = moe_lib.moe_params(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(RNG.standard_normal((2, 8, 32)), jnp.float32)
    base, _ = moe_lib.moe_apply(p, x, cfg)
    again, _ = moe_lib.moe_apply(p, x, cfg, ff_math=False)
    assert jnp.array_equal(base, again).item()      # default bitwise
    routed, _ = moe_lib.moe_apply(p, x, cfg, ff_math=True)
    assert np.abs(np.asarray(routed - base)).max() <= 1e-5


def test_negative_zero_sign_preserved():
    nz = np.float32([-0.0])
    x = FF(jnp.asarray(nz), jnp.asarray(np.float32([0.0])))
    for fn in ("expm1", "tanh", "erf", "gelu", "silu", "log1p"):
        h = np.asarray(getattr(ff, fn)(x, impl="jnp").hi)
        assert h[0] == 0.0 and np.signbit(h[0]), fn


def test_subnormal_inputs_degrade_gracefully():
    """Subnormal inputs behave as the FTZ hardware reads them (0-like for
    the odd functions, exactly 1 for exp) — no nans, right signs."""
    sub = np.float32([1e-45, -1e-45, 1.1754942e-38])
    x = FF(jnp.asarray(sub), jnp.zeros_like(jnp.asarray(sub)))
    assert (np.asarray(ff.exp(x, impl="jnp").hi) == 1.0).all()
    th = np.asarray(ff.tanh(x, impl="jnp").hi)
    assert np.isfinite(th).all() and abs(th).max() <= 1.2e-38


# ---------------------------------------------------------------------------
# dispatch / impl classes
# ---------------------------------------------------------------------------

ALL_OPS = tuple(sorted(ffmath.UNARY22)) + ("pow",)


def test_registry_registration_and_defaults():
    for op in ALL_OPS:
        assert op in dispatch.ops()
        assert set(dispatch.impls(op)) == {"jnp", "pallas", "f64", "fast"}
        # CPU default is the native-f64 tier, generic default the FF jnp
        assert dispatch._DEFAULTS[op] == {"*": "jnp", "cpu": "f64"}
        assert dispatch.resolve_name(op, "tuned_accurate") in ("f64", "jnp")


def test_pallas_bitwise_matches_jnp():
    """The kernel IS the jnp algorithm (same generic body, barrier-free
    EFTs): interpret-mode Pallas must match bitwise."""
    x64 = RNG.uniform(0.1, 4.0, (33, 150))   # inside every unary domain
    a, _ = _ff_in(x64)
    for op in sorted(ffmath.UNARY22):
        r1 = getattr(ff, op)(a, impl="jnp")
        r2 = getattr(ff, op)(a, impl="pallas", interpret=True)
        assert jnp.array_equal(r1.hi, r2.hi).item(), op
        assert jnp.array_equal(r1.lo, r2.lo).item(), op
    b, _ = _ff_in(RNG.uniform(-2, 2, (33, 150)))
    r1 = ff.pow(a, b, impl="jnp")
    r2 = ff.pow(a, b, impl="pallas", interpret=True)
    assert jnp.array_equal(r1.hi, r2.hi).item()
    assert jnp.array_equal(r1.lo, r2.lo).item()


def test_f64_impl_tighter_than_ff():
    x64 = RNG.uniform(-30, 30, 20000)
    a, xin = _ff_in(x64)
    out = ff.tanh(a, impl="f64")
    got = np.float64(np.asarray(out.hi)) + np.float64(np.asarray(out.lo))
    want = np.tanh(xin)
    rel = np.abs(got - want) / np.maximum(np.abs(want), 1e-300)
    assert rel.max() <= 2.0**-47


@pytest.mark.parametrize("mode", ["jit", "eager"])
def test_x64_literal_hazard_mitigated(mode):
    """PR 5's x64-scope pin, now owned by the shared corpus: the f64 impl
    must stay <= 2^-47 AND leave the trace-scoped x64 flag unleaked, per
    backend and per jit/eager (repro.verify.hazards carries the raw-path
    probe that shows why literal constants inside the scope are unsafe)."""
    from repro.verify import hazards

    rep = hazards.check_x64_literal_canonicalization(mode)
    assert rep.ok, rep.detail
    assert not jax.config.jax_enable_x64


@pytest.mark.parametrize("mode", ["jit", "eager"])
def test_constant_fold_hazard_mitigated(mode):
    """The PR 5 constant-folding pin, shared form: two_sum(x, <const>)
    keeps its residual under jit; the corpus also records whether the
    folding hazard is still live on this backend."""
    from repro.verify import hazards

    rep = hazards.check_constant_fold_two_sum(mode)
    assert rep.ok, rep.detail


def test_fast_impl_is_f32_class():
    """The documented escape hatch: hi == the f32 builtin, lo == 0."""
    x64 = RNG.uniform(-3, 3, 1000)
    a, _ = _ff_in(x64)
    out = ff.exp(a, impl="fast")
    assert jnp.array_equal(out.hi, jnp.exp(a.hi + a.lo)).item()
    assert not np.asarray(out.lo).any()


def test_tune_never_crowns_fast_or_f64_silently():
    from repro.ff import tuning
    for op in ALL_OPS:
        assert "fast" not in tuning._FAST_ELIGIBLE[op]
        assert tuning.accuracy_class(op, "fast") == "fast"
        assert tuning.accuracy_class(op, "jnp") == "accurate"


def test_math_ops_tunable(tmp_path, monkeypatch):
    from repro.ff import tuning
    monkeypatch.setenv(tuning.CACHE_ENV, str(tmp_path / "tune.json"))
    tuning.clear()
    try:
        out = ff.tune("exp", shapes=[(16, 128)], reps=1)
        rec = out["table"]["16x128"]
        assert rec["fast"]["impl"] in ("jnp", "f64")
        assert rec["accurate"]["impl"] in ("jnp", "f64")
    finally:
        tuning.clear()


# ---------------------------------------------------------------------------
# gradients (custom_vjp rules compute cotangents in FF)
# ---------------------------------------------------------------------------

GRAD_ORACLES = {
    "exp": lambda x: np.exp(x),
    "expm1": lambda x: np.exp(x),
    "log": lambda x: 1.0 / x,
    "log1p": lambda x: 1.0 / (1.0 + x),
    "tanh": lambda x: 1.0 / np.cosh(x) ** 2,
    "sigmoid": lambda x: (s := 1 / (1 + np.exp(-x))) * (1 - s),
    "erf": lambda x: 2.0 / np.sqrt(np.pi) * np.exp(-x * x),
    "gelu": lambda x: (0.5 * (1 + _ERF64(x / np.sqrt(2)))
                       + x * np.exp(-x * x / 2) / np.sqrt(2 * np.pi)),
    "silu": lambda x: (s := 1 / (1 + np.exp(-x))) * (1 + x * (1 - s)),
}


@pytest.mark.parametrize("fn", sorted(GRAD_ORACLES))
def test_grad_flows_in_ff(fn):
    x64 = RNG.uniform(0.05, 2.0, 256)
    a, xin = _ff_in(x64)

    g = jax.grad(lambda t: getattr(ff, fn)(t, impl="jnp").to_f32().sum())(a)
    assert isinstance(g, FF)
    got = np.float64(np.asarray(g.hi)) + np.float64(np.asarray(g.lo))
    want = GRAD_ORACLES[fn](xin)
    rel = np.abs(got - want) / np.maximum(np.abs(want), 1e-30)
    assert rel.max() <= 2.0**-40, f"{fn}: 2^{np.log2(rel.max()):.1f}"


def test_grad_pow_both_operands():
    a, ain = _ff_in(RNG.uniform(0.5, 3.0, 128))
    b, bin_ = _ff_in(RNG.uniform(-2.0, 2.0, 128))
    da, db = jax.grad(lambda x, y: ff.pow(x, y).to_f32().sum(),
                      argnums=(0, 1))(a, b)
    want_da = bin_ * ain ** (bin_ - 1)
    want_db = ain ** bin_ * np.log(ain)
    for g, want in ((da, want_da), (db, want_db)):
        got = np.float64(np.asarray(g.hi)) + np.float64(np.asarray(g.lo))
        rel = np.abs(got - want) / np.maximum(np.abs(want), 1e-30)
        assert rel.max() <= 2.0**-38


def test_grad_f32_operand_gets_f32_cotangent():
    x = jnp.asarray([0.3, 1.2], jnp.float32)
    g = jax.grad(lambda t: ff.exp(t).to_f32().sum())(x)
    assert g.dtype == jnp.float32 and g.shape == x.shape


# ---------------------------------------------------------------------------
# fusion: transcendentals in one-kernel chains
# ---------------------------------------------------------------------------

def _assert_bitwise(r1, r2):
    assert jnp.array_equal(r1.hi, r2.hi).item()
    assert jnp.array_equal(r1.lo, r2.lo).item()


def test_fused_transcendental_chain_bitwise_parity():
    """jnp executor (core barriers) vs interpret Pallas (eft): the chain
    a*exp(b) + tanh(a) - sigmoid(b) must agree bitwise."""
    a, _ = _ff_in(RNG.uniform(-1, 1, (24, 130)))
    b, _ = _ff_in(RNG.uniform(-1, 1, (24, 130)))
    fn = ff.fused(lambda x, y: x * fusion.exp(y) + fusion.tanh(x)
                  - fusion.sigmoid(y))
    _assert_bitwise(fn(a, b), fn(a, b, interpret=True))


def test_fused_log_exp_roundtrip_accuracy():
    """log(exp(x)) in ONE fused chain stays ~2^-42 of x — impossible with
    the old f32-only fexp/flog tracer ops (~2^-24)."""
    a, xin = _ff_in(RNG.uniform(-0.3, 0.3, (8, 128)))
    fn = ff.fused(lambda x: fusion.log(fusion.exp(x)))
    out = fn(a)
    got = np.float64(np.asarray(out.hi)) + np.float64(np.asarray(out.lo))
    assert np.abs(got - xin).max() <= 2.0**-42
    _assert_bitwise(out, fn(a, interpret=True))


def test_fused_f32_exp_log_still_builtin_bitwise():
    """f32 nodes keep the hardware exp/log (existing chains' bits)."""
    x = jnp.asarray(RNG.uniform(-1, 1, (8, 128)), jnp.float32)
    fn = ff.fused(lambda t: fusion.log(fusion.exp(t)))
    out = fn(x)
    assert jnp.array_equal(out, jnp.log(jnp.exp(x))).item()


def test_fused_transcendental_with_rowsum():
    a, xin = _ff_in(RNG.uniform(-1, 1, (16, 256)))
    fn = ff.fused(lambda x: fusion.exp(x).hi.sum())
    r1, r2 = fn(a), fn(a, interpret=True)
    # reduction chains: two compensated orders, <= 1 ulp (fusion contract)
    ulp = np.abs(np.asarray(r1.hi) - np.asarray(r2.hi)) / np.spacing(
        np.maximum(np.abs(np.asarray(r2.hi)), np.float32(1e-30)))
    assert ulp.max() <= 1.0
    # the chain reduces the f32-rounded .hi plane (rowsum takes f32
    # nodes), so the oracle is the exact sum of those rounded values
    e = ff.exp(a, impl="jnp")
    want = np.float64(np.asarray(e.hi)).sum(-1)
    got = np.float64(np.asarray(r1.hi)) + np.float64(np.asarray(r1.lo))
    assert np.abs(got / want - 1).max() <= 2.0**-40


def test_plane_count_surcharges_transcendentals():
    prog = ff.fused(lambda x: fusion.exp(x)).program(
        FF.zeros((8, 128)))
    base = ff.fused(lambda x: x * 1.0).program(FF.zeros((8, 128)))
    assert prog.plane_count() >= base.plane_count() + fusion._DEEP_OP_PLANES


# ---------------------------------------------------------------------------
# accurate-class softmax / logsumexp ("the fusion tracer's accuracy gap")
# ---------------------------------------------------------------------------

def _lse64(x):
    m = x.max(-1, keepdims=True)
    return (m + np.log(np.sum(np.exp(x - m), -1, keepdims=True)))[..., 0]


def test_logsumexp_ff_beats_f32_exp_impls():
    """The ulp-contract improvement test: vs the f64 oracle, the "ff" impl
    (FF exponentials + ff.math.log) stays correctly-rounded-class; the
    f32-builtin-exp impls carry a measurably larger worst-case error.

    The rows are centered so |lse| ~ 0.5: at large |lse| the output ulp
    (2^-24 |lse|) swamps the builtin-exp error and EVERY impl looks
    correctly rounded — the gap is only observable where the result's own
    ulp is small."""
    x = np.asarray(RNG.standard_normal((256, 2048)) * 4, np.float32)
    x = np.float32(x - _lse64(np.float64(x))[:, None] + 0.5)
    want = _lse64(np.float64(x))
    spacing = np.spacing(np.abs(want).astype(np.float32)).astype(np.float64)
    err_ff = np.abs(np.float64(np.asarray(
        ff.logsumexp(jnp.asarray(x), impl="ff"))) - want) / spacing
    err_jnp = np.abs(np.float64(np.asarray(
        ff.logsumexp(jnp.asarray(x), impl="jnp"))) - want) / spacing
    assert err_ff.max() <= 0.6             # correctly-rounded class
    assert err_jnp.max() > err_ff.max()    # the f32-exp error is visible


def test_softmax_ff_beats_f32_exp_impls():
    x = np.asarray(RNG.standard_normal((64, 512)) * 8, np.float32)
    x64 = np.float64(x)
    e = np.exp(x64 - x64.max(-1, keepdims=True))
    want = e / e.sum(-1, keepdims=True)

    def worst_rel(arr):
        return (np.abs(np.float64(arr) - want)
                / np.maximum(want, 1e-300)).max()

    got_ff = worst_rel(np.asarray(ff.softmax(jnp.asarray(x), impl="ff")))
    got_jnp = worst_rel(np.asarray(ff.softmax(jnp.asarray(x), impl="jnp")))
    assert got_ff <= 2.0**-23          # correctly-rounded f32 class
    assert got_ff < got_jnp / 2        # clear improvement, not noise
    # probabilities still normalize
    s = np.asarray(ff.softmax(jnp.asarray(x), impl="ff")).sum(-1)
    assert np.abs(s - 1).max() < 1e-6


def test_accurate_class_resolution():
    assert dispatch.resolve_name("logsumexp", "tuned_accurate",
                                 shape=(7, 333)) == "ff"
    assert dispatch.resolve_name("softmax", "tuned_accurate",
                                 shape=(7, 333)) == "ff"


def test_softmax_ff_kernel_parity_interpret():
    """The hand-fused accurate kernel (interpret mode) vs the jnp "ff"
    formulation: same FF exponentials, two compensated sum orders ->
    within 1 f32 ulp."""
    from repro.kernels import ff_fused
    x = jnp.asarray(RNG.standard_normal((16, 384)) * 5, jnp.float32)
    for mode in ("softmax", "logsumexp"):
        k = np.asarray(ff_fused.ff_softmax(x, mode=mode, accurate=True,
                                           interpret=True))
        if mode == "softmax":
            j = np.asarray(ff.softmax(x, impl="ff", interpret=False))
        else:
            j = np.asarray(ff.logsumexp(x, impl="ff", interpret=False))
        ulp = np.abs(k - j) / np.spacing(np.maximum(np.abs(j),
                                                    np.float32(1e-30)))
        assert ulp.max() <= 1.0, mode


# ---------------------------------------------------------------------------
# model-policy migration (satellite)
# ---------------------------------------------------------------------------

def test_policy_default_has_ff_math_off():
    from repro.core.policy import PrecisionPolicy
    for lvl in ("baseline", "ff_master", "ff_reduce", "ff_full"):
        assert PrecisionPolicy.make(lvl).ff_math is False
    assert PrecisionPolicy.make("ff_full", ff_math=True).ff_math is True


def test_mlp_gate_policy_switch_bitwise_default():
    from repro.models.layers import mlp_apply, mlp_params
    from repro.models.config import ModelConfig
    cfg = ModelConfig(name="t", family="dense", num_layers=1, d_model=32,
                      num_heads=4, num_kv_heads=4, d_ff=64, vocab_size=64)
    p = mlp_params(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(RNG.standard_normal((2, 8, 32)), jnp.float32)
    base = mlp_apply(p, x)
    assert jnp.array_equal(base, mlp_apply(p, x, ff_math=False)).item()
    routed = mlp_apply(p, x, ff_math=True)
    assert np.abs(np.asarray(routed - base)).max() <= 1e-5
    assert not jnp.array_equal(base, routed).item() or True  # may coincide


def test_softcap_policy_switch():
    from repro.models.layers import unembed_apply
    from repro.models.config import ModelConfig
    cfg = ModelConfig(name="t", family="dense", num_layers=1, d_model=16,
                      num_heads=2, num_kv_heads=2, d_ff=32, vocab_size=32,
                      logit_softcap=30.0, tie_embeddings=True)
    p = {"tok": jnp.asarray(RNG.standard_normal((32, 16)), jnp.float32)}
    x = jnp.asarray(RNG.standard_normal((2, 4, 16)) * 10, jnp.float32)
    base = unembed_apply(p, x, cfg)
    routed = unembed_apply(p, x, cfg, ff_math=True)
    c = 30.0
    want = c * np.tanh(np.float64(np.asarray(x @ p["tok"].T)) / c)
    assert (np.abs(np.float64(np.asarray(routed)) - want).max()
            <= np.abs(np.float64(np.asarray(base)) - want).max() + 1e-12)


def test_mamba2_decay_policy_switch():
    from repro.models import mamba2
    B, S, H, P, Nst = 1, 16, 2, 4, 8
    x = jnp.asarray(RNG.standard_normal((B, S, H, P)), jnp.float32)
    dt = jnp.asarray(np.abs(RNG.standard_normal((B, S, H))) * 0.1,
                     jnp.float32)
    A = -jnp.asarray(np.abs(RNG.standard_normal((H,))) + 0.1, jnp.float32)
    Bm = jnp.asarray(RNG.standard_normal((B, S, Nst)), jnp.float32)
    Cm = jnp.asarray(RNG.standard_normal((B, S, Nst)), jnp.float32)
    y0, f0 = mamba2.ssd_scan(x, dt, A, Bm, Cm)
    y0b, _ = mamba2.ssd_scan(x, dt, A, Bm, Cm, ff_math=False)
    assert jnp.array_equal(y0, y0b).item()          # default bitwise
    y1, f1 = mamba2.ssd_scan(x, dt, A, Bm, Cm, ff_math=True)
    assert np.abs(np.asarray(y1 - y0)).max() <= 1e-5
    assert np.abs(np.asarray(f1 - f0)).max() <= 1e-5


def test_token_logprob_policy_routing():
    """ff_math=True routes the score's normalizer through the accurate
    "ff" logsumexp (bitwise — the max-ERROR of the subtracted score is a
    rounding lottery between two sub-ulp-correct paths, so routing, not
    error ordering, is the contract)."""
    from repro.train.serve_step import token_logprob
    lg = jnp.asarray(RNG.standard_normal((3, 512)) * 4, jnp.float32)
    tk = jnp.asarray([1, 2, 3], jnp.int32)
    chosen = np.asarray(lg)[np.arange(3), np.asarray(tk)]
    base = token_logprob(lg, tk)
    with ff.policy("ff_reduce", ff_math=True):
        routed = token_logprob(lg, tk)
    want_routed = chosen - np.asarray(ff.logsumexp(lg, impl="ff"))
    want_base = chosen - np.asarray(ff.logsumexp(lg))
    assert np.array_equal(np.asarray(routed), want_routed)
    assert np.array_equal(np.asarray(base), want_base)
