"""The exhaustive seam sweeps, budget-gated through one code path.

``--sweep-budget`` (root conftest) sets points-per-seam: the default
2^16 runs everywhere; CI's verify job passes 2^20 (the acceptance
budget); ``--sweep-budget 4194304`` additionally unlocks the
``slow_sweep`` full-grid arms.  The seam *registry* lives with the
algorithms (``repro.core.ffmath.reduction_seams``) so a constant retune
moves the swept neighborhoods — completeness is asserted here."""

import math

import numpy as np
import pytest

from repro.core import ffmath
from repro.verify import sweeps

pytest.importorskip("mpmath")

SEAMS = ffmath.reduction_seams()
SEAM_IDS = [s.name for s in SEAMS]


# ---------------------------------------------------------------------------
# registry completeness: every documented boundary class is present
# ---------------------------------------------------------------------------

def test_registry_covers_every_documented_seam_class():
    names = {s.name for s in SEAMS}
    required = {
        # exp: Cody–Waite grid, saturation windows, flush bands, tiny
        "exp/cody_waite_half_k", "exp/cody_waite_integer_k",
        "exp/overflow_window", "exp/underflow_window", "exp/lo_flush_band",
        "exp/tiny_arguments", "exp/subnormal_arguments", "exp/specials",
        # log: frexp branch points, fold seam, cancellation, specials
        "log/binade_boundaries", "log/sqrt2_fold", "log/near_one",
        "log/specials",
        # tanh: branch seam, inner reduction grid, saturation, identity
        "tanh/small_large_seam", "tanh/expm1_k_boundaries",
        "tanh/saturation_window", "tanh/deep_saturation",
        "tanh/identity_band", "tanh/identity_edge", "tanh/specials",
    }
    assert required <= names, required - names
    for s in SEAMS:
        assert s.fn in ffmath.UNARY22
        assert s.kind in ("centers", "window", "points")
        assert s.check in ("contract", "identity", "special")


def test_seam_centers_track_live_constants():
    """The k-grid is derived from the live reduction constants — if the
    clip window or the ln2 split moves, the centers move with it."""
    by_name = {s.name: s for s in SEAMS}
    ln2 = ffmath._EXP_L1 + ffmath._EXP_L2
    half = by_name["exp/cody_waite_half_k"].data
    assert all(abs(c / ln2 % 1 - 0.5) < 1e-9 for c in half)
    assert min(half) >= ffmath._EXP_CLIP_LO - ln2
    assert max(half) <= ffmath._EXP_CLIP_HI + ln2
    seam = by_name["tanh/small_large_seam"]
    assert float(ffmath._TANH_SMALL) in seam.data


# ---------------------------------------------------------------------------
# point enumeration
# ---------------------------------------------------------------------------

def test_ordered_index_roundtrip_and_adjacency():
    xs = np.array([0.0, -0.0, 1.0, -1.0, 1e-40, -1e-40, 3.4e38, 2.0 ** -149],
                  np.float32)
    idx = sweeps.ordered_index(xs)
    back = sweeps.from_index(idx)
    assert (back.view(np.uint32)[2:] == xs.view(np.uint32)[2:]).all()
    # consecutive indices are consecutive floats
    one = np.float32(1.0)
    nxt = sweeps.from_index(sweeps.ordered_index(one) + 1)
    assert float(nxt) == float(np.nextafter(one, np.float32(2.0)))
    prv = sweeps.from_index(sweeps.ordered_index(one) - 1)
    assert float(prv) == float(np.nextafter(one, np.float32(0.0)))


def test_neighborhood_is_exhaustive_and_centered():
    pts = sweeps.neighborhood(1.0, 64)
    assert pts.size == 64
    u = np.unique(pts)
    assert u.size == 64                           # all distinct
    assert (np.float32(1.0) == pts).any()
    d = np.diff(sweeps.ordered_index(np.sort(pts)))
    assert (d == 1).all()                         # consecutive f32s


def test_window_full_enumeration_when_small():
    lo, hi = 1.0, float(np.float32(1.0) * (1 + 2 ** -18))
    pts = sweeps.window_points(lo, hi, 1 << 20)
    count = int(sweeps.ordered_index(np.float32(hi))
                - sweeps.ordered_index(np.float32(lo))) + 1
    assert pts.size == count                      # every float in [lo, hi]


def test_enumerate_respects_budget():
    for spec in SEAMS:
        pts = sweeps.enumerate_points(spec, 1 << 12)
        if spec.kind == "points":
            assert pts.size == len(spec.data)
        else:
            assert pts.size <= (1 << 12) + len(spec.data) * 32


# ---------------------------------------------------------------------------
# tolerance model units
# ---------------------------------------------------------------------------

def test_tolerance_bands():
    want = np.array([1.0, 2.0 ** -90, 2.0 ** -130, 0.5e38], np.float64)
    tol = sweeps.tolerances(want, 2.0 ** -42)
    assert tol[0] == 2.0 ** -42                   # normal band
    assert tol[1] == 2.0 ** -23                   # lo-flush band
    assert tol[2] == pytest.approx(2.0 ** -149 / 2.0 ** -130)  # subnormal
    assert tol[3] == 2.0 ** -42


# ---------------------------------------------------------------------------
# the sweeps themselves (budget-gated; this is the acceptance gate)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", SEAMS, ids=SEAM_IDS)
def test_seam_contract(spec, sweep_budget):
    r = sweeps.run_seam(spec, budget=sweep_budget)
    assert r.ok, (
        f"{r.seam}: {r.violations} violation(s) of {r.points} pts "
        f"(adjudicated {r.adjudicated}); worst {r.worst_points[:3]}")
    if spec.check == "contract" and spec.kind != "points":
        assert r.adjudicated > 0                  # the oracle actually ran


def test_sweep_exercises_the_core_jnp_path():
    """The sweep pins the CORE (jnp) implementation explicitly — the CPU
    dispatch default is the f64 tier, which must NOT be what the seam
    contract certifies."""
    import repro.ff.dispatch as dispatch
    assert dispatch._DEFAULTS["exp"]["cpu"] == "f64"
    spec = next(s for s in SEAMS if s.name == "exp/tiny_arguments")
    xs = sweeps.enumerate_points(spec, 256)
    h, l = sweeps.evaluate("exp", xs)
    want_h, want_l = ffmath.exp22(xs, np.zeros_like(xs), ffmath.CORE)
    assert (h.view(np.uint32) == np.asarray(want_h).view(np.uint32)).all()
    assert (l.view(np.uint32) == np.asarray(want_l).view(np.uint32)).all()


def test_ftz_acceptance_is_two_way_only_in_subnormal_range():
    """A zero output is accepted ONLY where the true result is subnormal
    (flush-to-zero hardware, paper §6.1) — a zero against a normal-range
    reference must still be a violation."""
    spec = ffmath.SeamSpec("synthetic/exp_normal", "exp", "points",
                           (0.5, 1.5), 2.0 ** -42, "contract", "")
    r = sweeps.run_seam(spec, budget=16)
    assert r.ok                                   # sanity: real exp passes
    # now a seam whose true results are subnormal: FTZ zeros are accepted
    spec2 = ffmath.SeamSpec("synthetic/exp_subnormal", "exp", "points",
                            (-95.0, -99.0), 2.0 ** -42, "contract", "")
    r2 = sweeps.run_seam(spec2, budget=16)
    assert r2.ok


def test_seam_sweep_reports_exclusions():
    """log's subnormal inputs are domain-excluded (counted, not judged)."""
    spec = ffmath.SeamSpec("synthetic/log_subnormal", "log", "points",
                           (1e-40, 1e-41, 0.5), 2.0 ** -42, "contract", "")
    r = sweeps.run_seam(spec, budget=4)
    assert r.excluded == 2
    assert r.ok


@pytest.mark.slow_sweep
@pytest.mark.parametrize("spec", SEAMS, ids=SEAM_IDS)
def test_seam_contract_full_grid(spec, sweep_budget):
    """The full-grid arm: same code path at the caller-chosen budget
    (e.g. --sweep-budget 4194304 for 2^22 per seam)."""
    r = sweeps.run_seam(spec, budget=sweep_budget)
    assert r.ok, (f"{r.seam}: {r.violations} violations; "
                  f"worst {r.worst_points[:3]}")
