"""Per-impl accuracy-ceiling tests for EVERY registered FF matmul
implementation: log2_err bounds vs the f64 oracle across K in {128, 512,
4096} and ragged/padded shapes, so a perf rewrite can't silently lose bits.

Also validates the Ozaki slicing machinery itself: parameter-heuristic
invariants, extraction exactness, skipped-pair error contribution, and the
wide-exponent-range escape hatch (``suggest_slices``)."""
import math

import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.ff as ff
from repro.core import ffmatmul


def _f64(x):
    return np.asarray(x).astype(np.float64)


# Accuracy ceilings (log2 of max |err|/|A||B|) per impl class.  Measured
# headroom >= 3 bits on multiple seeds at every shape below; a rewrite that
# loses bits trips these deterministically (fixed seed).
LOG2_CEILING = {
    "hybrid": -18.0, "pallas_hybrid": -18.0, "compensated": -18.0,
    "split": -18.0,
    "dot2": -44.0, "pallas_dot2": -44.0,
    "ozaki": -44.0, "pallas_ozaki": -44.0,
    "f64": -44.0,   # native dgemm lands ~2^-48; ozaki-kernel bound on TPU
    # mesh tier: outside an on_mesh scope (this file) these fall back to
    # the single-device impl of their class, so the class ceiling holds;
    # the on-mesh bounds are asserted in tests/test_sharded.py
    "sharded": -18.0, "sharded_accurate": -44.0,
}

SHAPES = [
    (32, 128, 32),
    (32, 512, 32),
    (32, 4096, 32),
    (100, 300, 97),     # ragged: every dim unaligned, K padded inside
    (64, 97, 33),       # K smaller than every block default
]


def _operands(mkn, seed=7):
    M, K, N = mkn
    rng = np.random.default_rng(seed)
    A = rng.standard_normal((M, K)).astype(np.float32)
    B = rng.standard_normal((K, N)).astype(np.float32)
    E = _f64(A) @ _f64(B)
    S = np.abs(_f64(A)) @ np.abs(_f64(B))
    return jnp.asarray(A), jnp.asarray(B), E, S


@pytest.mark.parametrize("mkn", SHAPES)
def test_every_registered_impl_accuracy_ceiling(mkn):
    A, B, E, S = _operands(mkn)
    missing = set(ff.impls("matmul")) - set(LOG2_CEILING)
    assert not missing, f"new matmul impls need a ceiling entry: {missing}"
    for impl in ff.impls("matmul"):
        C = ff.matmul(A, B, impl=impl)
        err = (np.abs(C.to_f64() - E) / S).max()
        log2_err = np.log2(max(err, 2.0 ** -60))
        assert log2_err <= LOG2_CEILING[impl], (impl, mkn, log2_err)


def test_accurate_tier_beats_naive_everywhere():
    """The accurate tier must not just meet its ceiling but dominate naive
    f32 by >= 18 bits (the 'paper accuracy' claim) at the headline shape."""
    A, B, E, S = _operands((128, 4096, 128))
    naive = (np.abs(_f64(jnp.asarray(A) @ jnp.asarray(B)) - E) / S).max()
    for impl in ("dot2", "ozaki", "f64"):
        C = ff.matmul(A, B, impl=impl)
        err = max((np.abs(C.to_f64() - E) / S).max(), 2.0 ** -60)
        assert np.log2(err) <= np.log2(naive) - 18, impl


# ---------------------------------------------------------------------------
# Ozaki slicing machinery
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("K", [16, 128, 300, 512, 1024, 4096, 65536])
def test_ozaki_params_invariants(K):
    n, beta, bk, max_order = ffmatmul.ozaki_params(K)
    t = math.ceil(math.log2(max(bk, 2)))
    # exactness budget: slice-pair block products sum exactly in f32
    assert 2 * beta + t <= 26, (K, beta, bk)
    # coverage: sliced significand reaches the full 24 bits...
    assert n * beta >= 24
    # ...with the small-K margin slice when the residual discount is weak
    if K <= 512:
        assert n * beta >= 27
    # chunking: bk divides the padded K and never exceeds 1024 by default
    assert bk <= 1024 and bk <= max(K, 1)
    # pair skipping threshold sits at FF precision
    assert max_order == 50 // beta
    # explicit overrides win
    assert ffmatmul.ozaki_params(K, slices=6)[0] == 6
    assert ffmatmul.ozaki_params(K, beta=7)[1] == 7
    # ...but cannot silently break the exactness budget
    with pytest.raises(ValueError, match="exactness budget"):
        ffmatmul.ozaki_params(K, beta=12)


def _ref_alignment_exponent(x, axis):
    """The implementation's alignment-exponent rule, mirrored in the test:
    f32 ceil(log2) repaired against an EXACT power of two (ldexp — jnp.exp2
    is polynomial-approximated and inexact at most integer exponents)."""
    mu = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    e = jnp.ceil(jnp.log2(jnp.maximum(mu, jnp.float32(1e-38))))
    ie = e.astype(jnp.int32)
    ie = jnp.where(jnp.ldexp(jnp.float32(1), ie) < mu, ie + 1, ie)
    return _f64(ie)


def test_extract_slices_exact_reconstruction(rng):
    x = jnp.asarray(rng.standard_normal((16, 256)).astype(np.float32) *
                    np.exp2(rng.integers(-8, 9, (16, 256))).astype(np.float32))
    n, beta = 4, 8
    parts, r = ffmatmul.extract_slices(x, 1, n, beta)
    # slices + residual reconstruct x EXACTLY (every extraction step is an
    # error-free transformation)
    total = _f64(r)
    for p in parts:
        total = total + _f64(p)
    assert np.array_equal(total, _f64(x))
    # every slice is <= 2^(beta-1) quanta of its row granularity (the 1.5
    # sigma extraction bound that the exactness budget relies on); mirror
    # the implementation's exponent rule to avoid spurious one-ulp
    # disagreements
    e = _ref_alignment_exponent(x, axis=1)
    for i, p in enumerate(parts):
        g = np.exp2(e + 1 - beta * (i + 1))
        q = _f64(p) / g
        assert np.array_equal(q, np.round(q)), f"slice {i} off-grid"
        assert np.abs(q).max() <= 2.0 ** (beta - 1), f"slice {i} overwide"


def test_extract_slices_exact_on_log2_boundary():
    """Rows whose max|x| sits just ABOVE a power of two are the f32-log2
    edge: a not-correctly-rounded log2 can land exactly on the integer,
    ceil then underestimates the alignment exponent by 1 and every slice
    silently gets twice its quanta budget (jnp.exp2 being inexact at most
    integer exponents can ALSO defeat a naive repair).  The exact
    ldexp-compare repair must keep the slice-width invariant on exactly
    these rows."""
    n, beta = 3, 8
    rows = []
    for ebit in (1, 8, 32, -32, 100):
        top = np.float32(np.exp2(ebit)) * (np.float32(1) + np.float32(2.0 ** -23))
        rows.append(np.full(64, top * 0.9, np.float32))
        rows[-1][0] = top                    # row max just above 2^ebit
    x = jnp.asarray(np.stack(rows))
    parts, r = ffmatmul.extract_slices(x, 1, n, beta)
    total = _f64(r)
    for p in parts:
        total = total + _f64(p)
    assert np.array_equal(total, _f64(x))
    mu = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    e = _ref_alignment_exponent(x, axis=1)
    assert np.all(np.exp2(e) >= _f64(mu)), "alignment exponent underestimated"
    for i, p in enumerate(parts):
        q = _f64(p) / np.exp2(e + 1 - beta * (i + 1))
        assert np.array_equal(q, np.round(q)), f"slice {i} off-grid"
        assert np.abs(q).max() <= 2.0 ** (beta - 1), f"slice {i} overwide"


def test_ozaki_skipped_pair_contribution(rng):
    """slices=6 activates negligible-pair skipping (orders > 50/beta); the
    skipped mass must sit below FF precision AND the result must still meet
    the accurate-tier ceiling."""
    M = N = 24
    K = 512
    A = rng.standard_normal((M, K)).astype(np.float32)
    B = rng.standard_normal((K, N)).astype(np.float32)
    E = _f64(A) @ _f64(B)
    S = np.abs(_f64(A)) @ np.abs(_f64(B))
    n, beta, bk, max_order = ffmatmul.ozaki_params(K, slices=6)
    assert 2 * (n - 1) > max_order, "test premise: some pairs are skipped"
    # reconstruct the skipped pairs in f64 and bound their contribution
    pa, _ = ffmatmul.extract_slices(jnp.asarray(A), 1, n, beta)
    pb, _ = ffmatmul.extract_slices(jnp.asarray(B), 0, n, beta)
    skipped = np.zeros((M, N))
    for i in range(n):
        for j in range(n):
            if i + j > max_order:
                skipped = skipped + np.abs(_f64(pa[i]) @ _f64(pb[j]))
    assert (skipped / S).max() < 2.0 ** -44
    C = ff.matmul(jnp.asarray(A), jnp.asarray(B), impl="ozaki", slices=6)
    err = (np.abs(C.to_f64() - E) / S).max()
    assert np.log2(max(err, 2.0 ** -60)) <= -44


def test_ozaki_wide_exponent_range_suggest_slices(rng):
    """Wide within-row exponent spread is the documented weakness of the
    default slice count; suggest_slices must widen coverage and recover
    accuracy."""
    M = N = 32
    K = 512
    A = (rng.standard_normal((M, K)) *
         10.0 ** rng.uniform(-6, 6, (M, K))).astype(np.float32)
    B = (rng.standard_normal((K, N)) *
         10.0 ** rng.uniform(-6, 6, (K, N))).astype(np.float32)
    E = _f64(A) @ _f64(B)
    S = np.abs(_f64(A)) @ np.abs(_f64(B))
    base = ffmatmul.ozaki_params(K)[0]
    n = ffmatmul.suggest_slices(A, B)
    assert n > base, "wide-range operands must get extra slices"

    def err_with(slices):
        C = ff.matmul(jnp.asarray(A), jnp.asarray(B), impl="ozaki",
                      slices=slices)
        return (np.abs(C.to_f64() - E) / S).max()

    # more slices extend exact coverage but also lengthen the Add22 combine
    # chain, so "suggested" is not strictly better on every draw — the
    # contract is that BOTH configurations stay in the accurate tier
    for e in (err_with(0), err_with(n)):
        assert np.log2(max(e, 2.0 ** -60)) <= -42


def test_f64_impl_scoped_x64(rng):
    """matmul_f64 must reach native-f64 accuracy WITHOUT the global x64
    flag, including when traced inside a caller's f32 jit (the enable_x64
    context scopes dtype promotion to the impl's own trace), and must not
    leak the flag."""
    import jax as _jax
    assert not _jax.config.jax_enable_x64, "suite premise: x64 off"
    A, B, E, S = _operands((32, 1024, 32))
    for call in (lambda a, b: ff.matmul(a, b, impl="f64"),
                 jax.jit(lambda a, b: ff.matmul(a, b, impl="f64"))):
        C = call(A, B)
        assert C.hi.dtype == jnp.float32 and C.lo.dtype == jnp.float32
        err = max((np.abs(C.to_f64() - E) / S).max(), 2.0 ** -60)
        # a true dgemm sits at ~2^-48; an impl that silently degraded to
        # f32 (the x64-canonicalization failure mode) lands at ~2^-21
        assert np.log2(err) <= -44.0
    assert not _jax.config.jax_enable_x64, "enable_x64 context leaked"


def test_f64_grad_flow(rng):
    """f64 rides the same matmul VJP meta as every other impl."""
    A = jnp.asarray(rng.standard_normal((8, 64)).astype(np.float32))
    Bi = rng.integers(-8, 9, (64, 8)).astype(np.float32)
    g = jax.grad(lambda t: ff.matmul(t, jnp.asarray(Bi),
                                     impl="f64").to_f32().sum())(A)
    want = np.broadcast_to(_f64(Bi).sum(axis=1), (8, 64))
    assert np.array_equal(_f64(g), want)


def test_ozaki_grad_flow(rng):
    """The accurate tier is threaded through the matmul VJP meta: grads
    flow through ozaki (and the fused kernel path) like any other impl."""
    A = jnp.asarray(rng.standard_normal((8, 256)).astype(np.float32))
    Bi = rng.integers(-8, 9, (256, 8)).astype(np.float32)
    B = jnp.asarray(Bi)
    for impl in ("ozaki", "pallas_ozaki"):
        g = jax.grad(lambda t: ff.matmul(t, B, impl=impl).to_f32().sum())(A)
        want = np.broadcast_to(_f64(Bi).sum(axis=1), (8, 256))
        assert np.array_equal(_f64(g), want), impl
