"""Model-layer correctness: flash attention vs naive, SSD vs recurrence,
decode-vs-forward consistency, MoE invariants."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models.config import ModelConfig
from repro.models.layers import flash_attention, decode_attention, apply_rope
from repro.models import mamba2
from repro.models import init_params, train_forward, prefill, decode_step, init_cache


def naive_attention(q, k, v, causal):
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    q5 = q.reshape(B, Sq, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", q5, k.astype(jnp.float32))
    s = s / np.sqrt(hd)
    if causal:
        mask = jnp.arange(Skv)[None, :] <= jnp.arange(Sq)[:, None]
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bkgqd", p, v.astype(jnp.float32))
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("shape", [(2, 128, 128, 8, 2, 32),
                                   (1, 100, 260, 4, 4, 16),
                                   (2, 64, 64, 6, 3, 64)])
def test_flash_vs_naive(rng, shape, causal):
    B, Sq, Skv, H, KV, hd = shape
    if causal:
        Skv = Sq
    q = jnp.asarray(rng.standard_normal((B, Sq, H, hd)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, Skv, KV, hd)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, Skv, KV, hd)).astype(np.float32))
    got = flash_attention(q, k, v, causal=causal, block_q=32, block_kv=48)
    want = naive_attention(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_matches_naive(rng):
    B, Smax, H, KV, hd = 2, 96, 8, 4, 32
    q = jnp.asarray(rng.standard_normal((B, 1, H, hd)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, Smax, KV, hd)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, Smax, KV, hd)).astype(np.float32))
    n = 57
    got = decode_attention(q, k, v, jnp.int32(n))
    want = naive_attention(q, k[:, :n], v[:, :n], causal=False)
    np.testing.assert_allclose(np.asarray(got)[:, 0], np.asarray(want)[:, 0],
                               rtol=2e-5, atol=2e-5)


def test_ssd_vs_naive_recurrence(rng):
    """Chunked SSD must equal the O(S·N) sequential recurrence."""
    B, S, H, P, N = 2, 300, 4, 8, 16
    x = jnp.asarray(rng.standard_normal((B, S, H, P)).astype(np.float32))
    dt = jnp.asarray(np.abs(rng.standard_normal((B, S, H))).astype(np.float32) * 0.1)
    A = -jnp.asarray(np.abs(rng.standard_normal(H)).astype(np.float32))
    Bm = jnp.asarray(rng.standard_normal((B, S, N)).astype(np.float32))
    Cm = jnp.asarray(rng.standard_normal((B, S, N)).astype(np.float32))

    y_got, final_got = mamba2.ssd_scan(x, dt, A, Bm, Cm)

    # naive recurrence: h_t = exp(A dt_t) h_{t-1} + dt_t B_t x_t ; y = C_t h_t
    def step(h, t):
        decay = jnp.exp(dt[:, t] * A[None, :])                # (B,H)
        dBx = jnp.einsum("bh,bn,bhp->bhpn", dt[:, t], Bm[:, t], x[:, t])
        h = h * decay[..., None, None] + dBx
        y = jnp.einsum("bhpn,bn->bhp", h, Cm[:, t])
        return h, y

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    final_want, ys = jax.lax.scan(step, h0, jnp.arange(S))
    y_want = ys.transpose(1, 0, 2, 3)                          # (B,S,H,P)
    np.testing.assert_allclose(np.asarray(y_got), np.asarray(y_want),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(final_got), np.asarray(final_want),
                               rtol=2e-4, atol=2e-4)


def test_ssd_decode_continues_scan(rng):
    """ssd_scan state then ssd one-token recurrence == scan over S+1."""
    B, S, H, P, N = 1, 130, 2, 4, 8
    x = jnp.asarray(rng.standard_normal((B, S + 1, H, P)).astype(np.float32))
    dt = jnp.asarray(np.abs(rng.standard_normal((B, S + 1, H))).astype(np.float32) * 0.1)
    A = -jnp.asarray(np.abs(rng.standard_normal(H)).astype(np.float32))
    Bm = jnp.asarray(rng.standard_normal((B, S + 1, N)).astype(np.float32))
    Cm = jnp.asarray(rng.standard_normal((B, S + 1, N)).astype(np.float32))

    y_full, _ = mamba2.ssd_scan(x, dt, A, Bm, Cm)
    _, state = mamba2.ssd_scan(x[:, :S], dt[:, :S], A, Bm[:, :S], Cm[:, :S])
    # one manual recurrence step
    decay = jnp.exp(dt[:, S] * A[None, :])
    dBx = jnp.einsum("bh,bn,bhp->bhpn", dt[:, S], Bm[:, S], x[:, S])
    h = state * decay[..., None, None] + dBx
    y_last = jnp.einsum("bhpn,bn->bhp", h, Cm[:, S])
    np.testing.assert_allclose(np.asarray(y_last), np.asarray(y_full[:, S]),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch_kw", [
    dict(name="t-dense", family="dense"),
    dict(name="t-moe", family="moe", moe_num_experts=4, moe_top_k=2,
         moe_d_ff=64, moe_capacity_factor=4.0),
    dict(name="t-mla", family="moe", use_mla=True, moe_num_experts=4,
         moe_top_k=2, moe_d_ff=64, moe_capacity_factor=4.0,
         kv_lora_rank=32, q_lora_rank=48, qk_nope_head_dim=16,
         qk_rope_head_dim=8, v_head_dim=16),
    dict(name="t-ssm", family="ssm", ssm_state=16, ssm_head_dim=16),
])
def test_prefill_decode_matches_forward(rng, arch_kw):
    """Teacher-forced decode must reproduce the training-forward logits.

    This is the strongest serving-correctness test: run S tokens through
    prefill, then decode token S; compare against train_forward logits at
    position S computed on the S+1-token sequence.  f32 compute to keep the
    comparison tight.
    """
    base = dict(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
        vocab_size=97, head_dim=16, max_seq_len=128, attn_block_q=32,
        attn_block_kv=32, compute_dtype="float32", remat=False,
        moe_capacity_factor=4.0)
    base.update(arch_kw)
    cfg = ModelConfig(**base)
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    B, S = 2, 33
    tokens = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    batch_full = {"tokens": tokens, "targets": tokens}

    # full-forward logits at position S-? : train_forward returns loss only,
    # so recompute logits via prefill on S+1 (its last-position logits are
    # position S's next-token distribution)
    cache1 = init_cache(cfg, B, 64, dtype=jnp.float32)
    want, _ = prefill(params, {"tokens": tokens}, cfg, cache1)

    cache2 = init_cache(cfg, B, 64, dtype=jnp.float32)
    _, cache2 = prefill(params, {"tokens": tokens[:, :S]}, cfg, cache2)
    got, _ = decode_step(params, tokens[:, S:S + 1], jnp.int32(S), cache2, cfg)

    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_moe_routing_invariants(rng):
    from repro.models import moe as moe_lib
    cfg = ModelConfig(name="t", family="moe", d_model=32,
                      moe_num_experts=8, moe_top_k=2, moe_d_ff=64,
                      moe_capacity_factor=8.0)
    key = jax.random.PRNGKey(0)
    p = moe_lib.moe_params(key, cfg)
    x = jnp.asarray(rng.standard_normal((2, 16, 32)).astype(np.float32))
    out, aux = moe_lib.moe_apply(p, x, cfg)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))
    assert float(aux) >= 1.0 - 1e-3   # Switch aux >= 1 at perfect balance
    # with huge capacity nothing is dropped: doubling capacity is a no-op
    cfg2 = ModelConfig(name="t", family="moe", d_model=32,
                       moe_num_experts=8, moe_top_k=2, moe_d_ff=64,
                       moe_capacity_factor=16.0)
    out2, _ = moe_lib.moe_apply(p, x, cfg2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2),
                               rtol=1e-5, atol=1e-5)


def test_rope_relative_shift_invariance(rng):
    """RoPE: scores depend only on relative positions."""
    hd = 32
    q = jnp.asarray(rng.standard_normal((1, 4, 1, hd)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((1, 4, 1, hd)).astype(np.float32))
    p0 = jnp.arange(4)[None, :]
    p1 = p0 + 1000
    s0 = jnp.einsum("bqhd,bkhd->bqk",
                    apply_rope(q, p0, 1e4), apply_rope(k, p0, 1e4))
    s1 = jnp.einsum("bqhd,bkhd->bqk",
                    apply_rope(q, p1, 1e4), apply_rope(k, p1, 1e4))
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1),
                               rtol=1e-3, atol=1e-3)
