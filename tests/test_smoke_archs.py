"""Per-architecture smoke tests (assignment requirement): reduced config of
the same family, one forward/train step on CPU, asserting output shapes and
no NaNs — plus a prefill+decode step for the serving path."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, SHAPES, get_config, cell_applicable
from repro.models import (init_params, train_forward, prefill, decode_step,
                          init_cache)


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


def _batch(cfg, key, B=2, S=64):
    b = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
         "targets": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        b["patches"] = jax.random.normal(
            key, (B, cfg.num_patches, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        b["frames"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch, key):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, key)
    batch = _batch(cfg, key)
    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(lambda p, b: train_forward(p, b, cfg),
                           has_aux=True))(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    # a full-vocab uniform guess gives ln(V); an untrained model must be close
    assert float(loss) < np.log(cfg.vocab_size) + 1.0
    for leaf in jax.tree_util.tree_leaves(grads):
        assert bool(jnp.all(jnp.isfinite(leaf))), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_serve_step(arch, key):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, key)
    B, S = 2, 64
    batch = _batch(cfg, key, B, S)
    extra = cfg.num_patches if cfg.family == "vlm" else 0
    cache = init_cache(cfg, B, 128 + extra)
    logits, cache = jax.jit(lambda p, b, c: prefill(p, b, cfg, c))(
        params, batch, cache)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    pos = jnp.int32(S + extra)
    logits2, cache2 = jax.jit(
        lambda p, t, c: decode_step(p, t, pos, c, cfg))(params, tok, cache)
    assert logits2.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2))), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_exactness(arch):
    """The full-size configs must carry the exact published dimensions."""
    cfg = get_config(arch)
    expect = {
        "minitron_4b": (32, 3072, 24, 8, 9216, 256000),
        "phi3_medium_14b": (40, 5120, 40, 10, 17920, 100352),
        "llama3_405b": (126, 16384, 128, 8, 53248, 128256),
        "granite_3_2b": (40, 2048, 32, 8, 8192, 49155),
        "internvl2_1b": (24, 896, 14, 2, 4864, 151655),
        "jamba_1_5_large_398b": (72, 8192, 64, 8, 24576, 65536),
        "deepseek_v2_236b": (60, 5120, 128, 128, 12288, 102400),
        "olmoe_1b_7b": (16, 2048, 16, 16, 1024, 50304),
        "whisper_medium": (24, 1024, 16, 16, 4096, 51865),
        "mamba2_370m": (48, 1024, 16, 16, 0, 50280),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expect, (arch, got, expect)


def test_moe_extras():
    ds = get_config("deepseek_v2_236b")
    assert (ds.moe_num_experts, ds.moe_top_k, ds.moe_shared_experts,
            ds.moe_d_ff, ds.kv_lora_rank) == (160, 6, 2, 1536, 512)
    ol = get_config("olmoe_1b_7b")
    assert (ol.moe_num_experts, ol.moe_top_k) == (64, 8)
    ja = get_config("jamba_1_5_large_398b")
    assert (ja.moe_num_experts, ja.moe_top_k, ja.attn_every) == (16, 2, 8)
    mb = get_config("mamba2_370m")
    assert mb.ssm_state == 128


def test_cell_skip_rules():
    n_cells = n_run = 0
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES:
            n_cells += 1
            ok, reason = cell_applicable(cfg, shape)
            if shape == "long_500k":
                assert ok == (cfg.family in ("ssm", "hybrid")), arch
            else:
                assert ok
            n_run += ok
    assert n_cells == 40
    assert n_run == 32 + 2 * 0 + 2 - 2  # 30 runnable + 2 sub-quadratic 500k


def test_cell_count_exact():
    runnable = [1 for a in ARCHS for s in SHAPES
                if cell_applicable(get_config(a), s)[0]]
    assert len(runnable) == 32  # 40 - 8 full-attention long_500k skips
