"""Sharding rules + dry-run machinery tests.

SPMD lowering tests run in a SUBPROCESS with a small simulated device count
(conftest keeps the main test process at 1 device by design).
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _sub(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_param_spec_rules():
    import jax
    from repro.configs import get_config
    from repro.distributed import sharding as shd
    from repro.models import init_params

    # 1-device mesh with both axis names still produces valid specs
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = get_config("olmoe_1b_7b")
    params = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.random.PRNGKey(0))
    sh = shd.param_shardings(params, cfg, mesh)
    flat = jax.tree_util.tree_flatten_with_path(sh)[0]
    assert len(flat) > 10
    # every leaf got a NamedSharding
    for _, s in flat:
        assert s.mesh is not None


def test_validate_spec_divisibility():
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import validate_spec

    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    spec = validate_spec(P("model", "data"), (49155, 2048), FakeMesh())
    assert spec == P(None, "data")
    spec = validate_spec(P(("data", "model"), None), (512, 64), FakeMesh())
    assert spec == P(("data", "model"), None)
    spec = validate_spec(P(("data", "model"), None), (100, 64), FakeMesh())
    assert spec == P(None, None)


def test_dryrun_cell_subprocess_small_mesh():
    """Full dry-run machinery on a 2x4 mesh with a reduced config: lower,
    compile, memory+cost analysis, collective parsing."""
    code = """
import json
import jax
from repro.configs import SHAPES
from repro.configs import get_config
from repro.core.policy import PrecisionPolicy
from repro.launch import dryrun as dr
import dataclasses

cfg = get_config('granite_3_2b').reduced(num_layers=2, vocab_size=512)
spec = dataclasses.replace(SHAPES['train_4k'], seq_len=256, global_batch=8)
mesh = jax.make_mesh((2, 4), ('data', 'model'))
lowered, compiled = dr._lower_cell(cfg, spec, mesh, PrecisionPolicy.make('ff_master'))
from repro.launch import hlo_costs, hlo_analysis as hla
parsed = hlo_costs.analyze_text(compiled.as_text())
mem = hla.memory_summary(compiled)
print(json.dumps({'flops': parsed['flops'], 'coll': parsed['collective_bytes'],
                  'temp': mem['temp_size_in_bytes']}))
"""
    out = _sub(code, devices=8)
    res = json.loads(out.strip().splitlines()[-1])
    assert res["flops"] > 1e8          # nontrivial compute counted
    assert res["coll"] > 0             # sharded -> collectives exist
    assert res["temp"] > 0


def test_dryrun_decode_cell_subprocess():
    code = """
import json, dataclasses
import jax
from repro.configs import SHAPES, get_config
from repro.core.policy import PrecisionPolicy
from repro.launch import dryrun as dr
from repro.launch import hlo_costs

cfg = get_config('mamba2_370m').reduced(num_layers=2, vocab_size=512)
spec = dataclasses.replace(SHAPES['decode_32k'], seq_len=1024, global_batch=8)
mesh = jax.make_mesh((2, 4), ('data', 'model'))
lowered, compiled = dr._lower_cell(cfg, spec, mesh, PrecisionPolicy.make('ff_master'))
parsed = hlo_costs.analyze_text(compiled.as_text())
print(json.dumps({'flops': parsed['flops']}))
"""
    out = _sub(code, devices=8)
    res = json.loads(out.strip().splitlines()[-1])
    assert res["flops"] > 1e5


def test_hlo_costs_loop_multiplication():
    """The cost parser must multiply while bodies by trip count (the reason
    it exists — XLA's cost_analysis counts them once)."""
    import jax
    import jax.numpy as jnp
    from jax import lax
    from repro.launch.hlo_costs import analyze_text

    def f(w, x):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = lax.scan(body, x, w)
        return y.sum()

    L, D = 16, 64
    txt = jax.jit(f).lower(
        jax.ShapeDtypeStruct((L, D, D), jnp.float32),
        jax.ShapeDtypeStruct((4, D), jnp.float32)).compile().as_text()
    t = analyze_text(txt)
    expect = L * 2 * 4 * D * D
    assert t["flops"] >= expect, (t["flops"], expect)
    assert t["flops"] < expect * 3


def test_hlo_costs_exact_on_plain_dot():
    import jax
    import jax.numpy as jnp
    from repro.launch.hlo_costs import analyze_text

    M = K = N = 128
    txt = jax.jit(lambda a, b: a @ b).lower(
        jax.ShapeDtypeStruct((M, K), jnp.float32),
        jax.ShapeDtypeStruct((K, N), jnp.float32)).compile().as_text()
    t = analyze_text(txt)
    assert t["flops"] == 2 * M * K * N


def test_elastic_reshard_subprocess():
    """Elasticity: checkpoint written under one mesh restores onto a
    different device count (4 -> 8 devices) with identical values."""
    code = """
import json, tempfile, os
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import checkpoint as ckpt

devs = jax.devices()
n = len(devs)
mesh_a = jax.make_mesh((n // 4, 4), ("data", "model"))
tree = {"w": jnp.arange(64 * 8, dtype=jnp.float32).reshape(64, 8)}
sharded = jax.device_put(tree, NamedSharding(mesh_a, P("data", "model")))
d = tempfile.mkdtemp()
ckpt.save(d, 1, sharded)

# restart onto a different mesh shape (elastic scale-up of model axis)
mesh_b = jax.make_mesh((n // 8, 8), ("data", "model"))
restored, step, _ = ckpt.load(d, tree)
resharded = jax.device_put(restored, NamedSharding(mesh_b, P("data", "model")))
ok = bool(jnp.all(resharded["w"] == tree["w"]))
print(json.dumps({"ok": ok, "nshards_a": 4, "nshards_b": 8}))
"""
    out = _sub(code, devices=8)
    res = json.loads(out.strip().splitlines()[-1])
    assert res["ok"]
