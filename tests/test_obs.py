"""Observability subsystem: metrics registry, dispatch telemetry, guard
violation accumulation, and the serving request trace.

Contracts under test (docs/DESIGN_observability.md):
  * the registry's counter/gauge/histogram primitives, the snapshot/delta
    API, and both expositions (JSON, Prometheus text 0.0.4);
  * ``ff.dispatch.resolve_name`` records one resolution counter per
    (op, impl, source, backend, shape-bucket) naming the winning impl —
    and recording happens at trace time only, so jit steady-state is
    untouched;
  * ``GuardScope.record`` keeps accumulating the per-(op, kind)
    ``ff_guard_violations_total`` counter after the first (warn-once
    suppressed) warning;
  * the engine's request trace has IDENTICAL span structure under
    sync_every=1 and sync_every=4 (spans mark lifecycle transitions, not
    host syncs), exports as Perfetto-loadable Chrome JSON, and keeps
    timestamps monotone.
"""

import json
import warnings

import numpy as np
import pytest
import jax
import jax.numpy as jnp

import repro.ff as ff
from repro import obs
from repro.ff.guard import FFGuardWarning, GuardScope
from repro.models import init_params
from repro.models.config import ModelConfig
from repro.obs.registry import LOG2_BUCKETS, MetricsRegistry
from repro.obs.trace import TraceRecorder
from repro.serve import Request, ServeEngine


# --------------------------------------------------------------------------
# registry primitives
# --------------------------------------------------------------------------

def test_counter_gauge_labels():
    reg = MetricsRegistry()
    c = reg.counter("req_total", status="OK")
    c.inc()
    c.inc(4)
    assert c.value == 5
    # same (name, labels) -> same series; different labels -> different
    assert reg.counter("req_total", status="OK") is c
    assert reg.counter("req_total", status="TIMEOUT") is not c
    g = reg.gauge("depth")
    g.set(7)
    g.inc(-2)
    assert g.value == 5
    snap = reg.snapshot()
    assert snap["counters"]['req_total{status="OK"}'] == 5
    assert snap["gauges"]["depth"] == 5


def test_histogram_log2_buckets():
    reg = MetricsRegistry()
    h = reg.histogram("lat_seconds")
    for v in (1e-6, 1e-3, 1e-3, 0.5, 100.0):   # 100s -> +Inf overflow
        h.observe(v)
    snap = reg.snapshot()["histograms"]["lat_seconds"]
    assert snap["count"] == 5
    assert snap["sum"] == pytest.approx(100.502001, rel=1e-6)
    buckets = snap["buckets"]
    assert len(buckets) == len(LOG2_BUCKETS) + 1
    assert buckets[-1][0] == "+Inf" and buckets[-1][1] == 5
    # cumulative and monotone
    counts = [n for _, n in buckets]
    assert counts == sorted(counts)
    # 1e-6 lands in the first (<= 2^-20 s ~ 0.95us... next) buckets; the
    # precise invariant: every observation <= its bucket's upper bound
    assert counts[0] <= 1


def test_snapshot_delta():
    reg = MetricsRegistry()
    c = reg.counter("n")
    c.inc(3)
    before = reg.snapshot()
    c.inc(2)
    reg.gauge("g").set(9)
    reg.histogram("h").observe(0.01)
    d = reg.delta(before)
    assert d["counters"]["n"] == 2
    assert d["gauges"]["g"] == 9           # gauges pass through
    assert d["histograms"]["h"]["count"] == 1


def test_prometheus_exposition():
    reg = MetricsRegistry()
    reg.counter("req_total", status="OK").inc(2)
    reg.histogram("lat_seconds").observe(0.25)
    text = reg.to_prometheus()
    assert "# TYPE req_total counter" in text
    assert 'req_total{status="OK"} 2' in text
    assert "# TYPE lat_seconds histogram" in text
    assert 'lat_seconds_bucket{le="+Inf"} 1' in text
    assert "lat_seconds_count 1" in text
    # every line parses as `name{labels} value` or comment
    for line in text.splitlines():
        assert line.startswith("#") or len(line.rsplit(" ", 1)) == 2
    assert json.loads(reg.to_json())


# --------------------------------------------------------------------------
# dispatch telemetry
# --------------------------------------------------------------------------

def test_dispatch_resolution_counters():
    """resolve_name records the winning impl + source per op; an explicit
    impl= call shows source=explicit, a bare call shows the fall-through
    source, and the matmul series carries the MxKxN shape bucket.

    Local rng (not the session fixture): see
    test_paged_dirty_page_reuse_masked."""
    rng = np.random.default_rng(47)
    a = jnp.asarray(rng.standard_normal((32, 32)).astype(np.float32))
    before = obs.REGISTRY.snapshot()
    ff.matmul(a, a, impl="compensated").to_f32().block_until_ready()
    ff.add(a, a)
    d = obs.REGISTRY.delta(before)["counters"]
    hits = {s: n for s, n in d.items()
            if n and s.startswith("ff_dispatch_resolutions_total")}
    assert any('op="matmul"' in s and 'impl="compensated"' in s
               and 'source="explicit"' in s for s in hits)
    assert any('op="matmul"' in s and 'shape="32x32x32"' in s for s in hits)
    assert any('op="add"' in s for s in hits)


def test_dispatch_telemetry_is_trace_time_only():
    """A jitted FF op resolves at trace time; re-running the compiled
    program must not move the resolution counters."""
    rng = np.random.default_rng(48)
    a = jnp.asarray(rng.standard_normal((16, 16)).astype(np.float32))

    @jax.jit
    def f(x):
        return ff.matmul(x, x, impl="compensated").to_f32()

    f(a).block_until_ready()               # trace + compile: counters move
    before = obs.REGISTRY.snapshot()
    for _ in range(3):
        f(a).block_until_ready()           # steady state: no re-trace
    d = obs.REGISTRY.delta(before)["counters"]
    assert not any(n for s, n in d.items()
                   if s.startswith("ff_dispatch_resolutions_total"))


# --------------------------------------------------------------------------
# guard accumulation past warn-once (satellite fix)
# --------------------------------------------------------------------------

def test_guard_violations_accumulate_past_warn_once():
    """The FFGuardWarning is warn-once per (op, kind), but the
    ``ff_guard_violations_total`` obs counter must keep growing on every
    subsequent record() call."""
    scope = GuardScope("check")
    before = obs.REGISTRY.snapshot()
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        for _ in range(4):
            scope.record("matmul", "nonfinite", 2)
    guard_warns = [w for w in caught
                   if issubclass(w.category, FFGuardWarning)]
    assert len(guard_warns) == 1, "user-facing warning is warn-once"
    assert scope.counters[("matmul", "nonfinite")] == 8
    d = obs.REGISTRY.delta(before)["counters"]
    series = 'ff_guard_violations_total{kind="nonfinite",op="matmul"}'
    assert d.get(series) == 8, (
        f"obs counter stopped at {d.get(series)} — must accumulate all 4 "
        f"record() calls, not just the warned one")
    warn_series = 'ff_warnings_total{kind="guard"}'
    assert d.get(warn_series, 0) == 1


# --------------------------------------------------------------------------
# serving request trace
# --------------------------------------------------------------------------

CFG = ModelConfig(name="obs-test", family="dense", num_layers=2,
                  d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
                  vocab_size=512, max_seq_len=128, compute_dtype="float32",
                  remat=False)


@pytest.fixture(scope="module")
def served():
    return init_params(CFG, jax.random.PRNGKey(0))


def _mixed_requests(rng, n, max_new):
    lens = rng.integers(5, 23, size=n)
    return [Request(uid=i,
                    prompt=rng.integers(1, CFG.vocab_size,
                                        size=int(l)).astype(np.int32),
                    max_new=max_new)
            for i, l in enumerate(lens)]


def test_trace_structure_invariant_under_sync_every(served):
    """sync_every=4 batches device_gets but must not change the request
    lifecycle: both engines produce the SAME span structure (one queued +
    prefill + decode + request span per uid, same terminal statuses) and
    the same tokens.  The trace exports as Chrome JSON that survives a
    json round-trip with monotone timestamps."""
    reqs = _mixed_requests(np.random.default_rng(41), 3, max_new=7)
    structures, results = {}, {}
    for n in (1, 4):
        eng = ServeEngine(served, CFG, max_batch=2, page_size=8,
                          max_ctx=48, sync_every=n, obs=obs.Observer())
        for r in reqs:
            eng.submit(Request(uid=r.uid, prompt=r.prompt,
                               max_new=r.max_new))
        results[n] = eng.run()
        structures[n] = eng.obs.trace.span_structure()

        payload = json.loads(json.dumps(eng.obs.to_chrome_trace()))
        assert payload["traceEvents"], "trace must not be empty"
        ts = [e["ts"] for e in payload["traceEvents"] if e["ph"] != "M"]
        assert ts == sorted(ts) and all(t >= 0 for t in ts)
        spans = [e for e in payload["traceEvents"] if e["ph"] == "X"]
        assert all(e["dur"] >= 0 for e in spans)
        for r in reqs:                     # exactly one lifecycle each
            tid = eng.obs.trace.request_tid(r.uid)
            names = sorted(e["name"] for e in spans if e["tid"] == tid)
            assert names == ["decode", "prefill", "queued", "request"]

    assert structures[1] == structures[4], (
        "span structure must be a lifecycle invariant, not a function of "
        "host-sync batching")
    for r in reqs:
        assert np.array_equal(results[1][r.uid].tokens,
                              results[4][r.uid].tokens)
        assert results[1][r.uid].status == results[4][r.uid].status


def test_engine_metrics_populated(served):
    """A plain run populates the per-engine counters and latency
    histograms, and token accounting agrees with the results."""
    reqs = _mixed_requests(np.random.default_rng(42), 3, max_new=6)
    eng = ServeEngine(served, CFG, max_batch=2, page_size=8, max_ctx=48)
    for r in reqs:
        eng.submit(r)
    res = eng.run()
    snap = eng.obs.snapshot()
    assert snap["counters"]['serve_requests_total{status="OK"}'] == 3
    emitted = sum(len(r.tokens) for r in res.values())
    assert snap["counters"]["serve_tokens_emitted_total"] == emitted
    for h in ("serve_prefill_seconds", "serve_decode_step_seconds",
              "serve_flush_seconds"):
        assert snap["histograms"][h]["count"] > 0, h


def test_trace_recorder_primitives():
    rec = TraceRecorder()
    rec.name_request_track(5)
    t0 = rec.now()
    rec.complete("request", t0, 10.0, tid=rec.request_tid(5),
                 args={"status": "OK"})
    rec.instant("quarantine", tid=0, args={"uid": 5})
    rec.counter("queue", {"depth": 2})
    out = rec.to_chrome_trace()
    assert out["displayTimeUnit"] == "ms"
    phs = [e["ph"] for e in out["traceEvents"]]
    # metadata first, then timestamp-sorted events
    assert phs[0] == "M" and set(phs) == {"M", "X", "i", "C"}
    assert rec.span_structure() == [(rec.request_tid(5), "request", "OK")]
