"""``ff.guard`` contracts: the fused health probe (jnp == pallas), the
typed FFError taxonomy, and the scoped check/degrade policy.

The invariant probed is the paper's FF normalization contract via its
multiplicative surrogate ``|lo| <= 2^-24 |hi|`` (exact for power-of-two
``hi``, within one binade everywhere — accepts every normalized pair,
flags anything at least 2x out).  Subnormal ``lo`` is a separate hazard
flag (flush-to-zero hardware), NOT a violation — legal FF pairs can have
subnormal low limbs.  See docs/DESIGN_robustness.md.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

import repro.ff as ff
from repro.core.ff import FF
from repro.ff import dispatch
from repro.ff.guard import (FFError, FFGuardWarning, FFNonFiniteError,
                            FFNormalizationError, current_guard, protect,
                            report_violation)
from repro.kernels.ff_guard import HALF_ULP_SURROGATE, flag_planes


@pytest.fixture
def rng():
    """File-local override of the conftest session rng: guard tests must
    not advance the suite-wide stream — downstream accuracy tests were
    calibrated against its unshifted draw sequence."""
    return np.random.default_rng(778)


def _healthy_ff(rng, shape=(4, 64)):
    return FF.from_f32(jnp.asarray(
        rng.standard_normal(shape) * 3.0, jnp.float32))


def _poisoned_pair():
    """(hi, lo) planes: 2 nonfinite, 1 unnormalized, 1 denormal-lo, and a
    healthy in-bound pair at index 2 (2^-30 <= 2 * 2^-24)."""
    hi = jnp.asarray([1.0, np.nan, 2.0, np.inf, 4.0, 1.0], jnp.float32)
    lo = jnp.asarray([0.0, 0.0, 2.0 ** -30, 0.0, 0.25, 2.0 ** -130],
                     jnp.float32)
    return hi, lo


# --------------------------------------------------------------------------
# probe
# --------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_probe_healthy_is_zero(rng, impl):
    """Normalized FF pairs (the output contract of every FF op) carry no
    violations under either probe implementation."""
    x = _healthy_ff(rng)
    c = ff.guard_probe(x, impl=impl)
    assert int(c.nonfinite) == 0
    assert int(c.unnormalized) == 0
    assert int(c.violations) == 0


@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_probe_counts_by_category(impl):
    hi, lo = _poisoned_pair()
    c = ff.guard_probe(hi, lo, impl=impl)
    assert int(c.nonfinite) == 2
    assert int(c.unnormalized) == 1
    # subnormal lo is a hazard, not a violation — and it is detected via
    # limb BITS, because a float compare is itself DAZ-flushed on some
    # backends (the exact hazard the flag reports)
    assert int(c.denormal_lo) == 1
    assert int(c.violations) == 3


def test_probe_impls_agree(rng):
    """jnp and pallas probes agree plane-for-plane, including the
    subnormal detection (bit inspection on both paths)."""
    hi = jnp.asarray(rng.standard_normal((3, 40)), jnp.float32)
    lo = hi * jnp.float32(HALF_ULP_SURROGATE) * jnp.asarray(
        rng.uniform(0.0, 2.0, (3, 40)), jnp.float32)
    a = ff.guard_probe(hi, lo, impl="jnp")
    b = ff.guard_probe(hi, lo, impl="pallas")
    assert tuple(map(int, a)) == tuple(map(int, b))


def test_probe_surrogate_boundary():
    """|lo| exactly at 2^-24 |hi| is healthy; one ulp above is flagged;
    hi = 0 requires lo = 0."""
    hi = jnp.asarray([1.0, 1.0, 0.0, 0.0], jnp.float32)
    lo = jnp.asarray([2.0 ** -24, 2.0 ** -23, 0.0, 1e-3], jnp.float32)
    nf, un, _ = flag_planes(hi, lo)
    assert not bool(nf.any())
    assert np.array_equal(np.asarray(un), [False, True, False, True])


def test_health_mask_and_plain_f32(rng):
    hi, lo = _poisoned_pair()
    m = np.asarray(ff.health_mask(hi, lo))
    # denormal lo (index 5) is a hazard, not a violation -> still healthy
    assert m.tolist() == [True, False, True, False, False, True]
    # plain f32 arrays probe as (x, 0) pairs: finiteness only
    x = jnp.asarray([1.0, np.inf, 3.0], jnp.float32)
    assert np.asarray(ff.health_mask(x)).tolist() == [True, False, True]


def test_probe_nan_does_not_leak_categories():
    """NaN limbs count ONLY as nonfinite (NaN comparisons must not bleed
    into the normalization / subnormal categories)."""
    hi = jnp.asarray([np.nan], jnp.float32)
    lo = jnp.asarray([np.nan], jnp.float32)
    c = ff.guard_probe(hi, lo)
    assert (int(c.nonfinite), int(c.unnormalized),
            int(c.denormal_lo)) == (1, 0, 0)


# --------------------------------------------------------------------------
# taxonomy
# --------------------------------------------------------------------------

def test_assert_healthy_taxonomy():
    ff.assert_healthy(jnp.asarray([1.0, 2.0], jnp.float32))
    with pytest.raises(FFNonFiniteError) as ei:
        ff.assert_healthy(jnp.asarray([np.inf], jnp.float32), op="matmul")
    assert ei.value.op == "matmul" and ei.value.kind == "nonfinite"
    assert isinstance(ei.value, FFError)
    with pytest.raises(FFNormalizationError) as ei:
        ff.assert_healthy(jnp.asarray([1.0], jnp.float32),
                          jnp.asarray([0.5], jnp.float32), op="add")
    assert ei.value.kind == "unnormalized"
    # nonfinite outranks unnormalized when both are present
    hi, lo = _poisoned_pair()
    with pytest.raises(FFNonFiniteError):
        ff.assert_healthy(hi, lo)


# --------------------------------------------------------------------------
# scoped policy: off / check / degrade
# --------------------------------------------------------------------------

def test_guard_scope_stack_and_modes():
    assert current_guard().mode == "off"
    with ff.guard(mode="check") as g:
        assert current_guard() is g
        with ff.guard(mode="degrade"):
            assert current_guard().mode == "degrade"
        assert current_guard().mode == "check"
    assert current_guard().mode == "off"
    with pytest.raises(ValueError):
        ff.guard(mode="loud")


def test_check_mode_counts_without_changing_values(rng):
    x = FF(jnp.asarray([1.0, np.inf, 2.0], jnp.float32),
           jnp.zeros((3,), jnp.float32))
    with pytest.warns(FFGuardWarning):
        with ff.guard(mode="check") as g:
            y = protect("softmax", x)
            np.testing.assert_array_equal(
                np.asarray(y.hi), np.asarray(x.hi))   # pass-through
    assert g.counters[("softmax", "nonfinite")] == 1
    assert ("softmax", "unnormalized") not in g.counters
    assert not g.degraded                             # check never degrades


def test_degrade_mode_repairs_and_reresolves():
    """A violation under mode="degrade" (1) repairs the poisoned lanes,
    (2) records the op, (3) drops that op's future resolution one
    accuracy class (ff -> fast f32) INSIDE the scope only."""
    before = dispatch.resolve_name("softmax", None)
    x = FF(jnp.asarray([1.0, np.inf, 2.0], jnp.float32),
           jnp.zeros((3,), jnp.float32))
    with pytest.warns(FFGuardWarning):
        with ff.guard(mode="degrade") as g:
            y = protect("softmax", x)
            assert np.asarray(jnp.isfinite(y.hi)).all()
            assert "softmax" in g.degraded
            inside = dispatch.resolve_name("softmax", None)
    from repro.ff.tuning import accuracy_class
    assert accuracy_class("softmax", inside) == "fast"
    assert dispatch.resolve_name("softmax", None) == before  # scope exited


def test_degrade_counts_under_jit():
    """The probe + counter callback survive jit (jax.debug.callback), and
    the repaired value comes out of the compiled function."""
    x = FF(jnp.asarray([1.0, np.inf, 2.0], jnp.float32),
           jnp.zeros((3,), jnp.float32))
    f = jax.jit(lambda v: protect("log", v).hi)
    with pytest.warns(FFGuardWarning):
        with ff.guard(mode="degrade") as g:
            out = np.asarray(jax.block_until_ready(f(x)))
    assert np.isfinite(out).all()
    assert g.counters[("log", "nonfinite")] == 1


def test_off_mode_is_identity(rng):
    x = FF(jnp.asarray([np.nan, 1.0], jnp.float32),
           jnp.zeros((2,), jnp.float32))
    y = protect("exp", x)       # no ambient scope -> structural no-op
    assert y is x
    assert current_guard().counters == {}


def test_report_violation_explicit():
    with ff.guard(mode="degrade") as g:
        with pytest.warns(FFGuardWarning):
            report_violation("matmul", "nonfinite", 3)
        assert g.counters[("matmul", "nonfinite")] == 3
        assert "matmul" in g.degraded
        name = dispatch.resolve_name("matmul", None)
    from repro.ff.tuning import accuracy_class
    assert accuracy_class("matmul", name) == "fast"


def test_math_ops_route_through_guard():
    """ff.math results pass through the ambient guard: a non-finite
    ff.log output is counted and repaired under mode="degrade"."""
    x = jnp.asarray([0.5, -1.0, 2.0], jnp.float32)   # log(-1) = nan
    with pytest.warns(FFGuardWarning):
        with ff.guard(mode="degrade") as g:
            y = ff.log(x)
            assert np.asarray(jnp.isfinite(y.hi)).all()
    assert g.counters[("log", "nonfinite")] == 1
    assert "log" in g.degraded
    # outside any scope the same call keeps its honest nan
    assert not np.isfinite(np.asarray(ff.log(x).hi))[1]


def test_grad_through_protect(rng):
    """protect() is differentiable (the probe is data-independent of the
    gradient path when healthy)."""
    x = _healthy_ff(rng, (8,))
    def loss(hi):
        return protect("exp", FF(hi, x.lo)).to_f32().sum()
    with ff.guard(mode="degrade"):
        g = jax.grad(loss)(x.hi)
    assert np.isfinite(np.asarray(g)).all()
