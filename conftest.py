"""Repo-root conftest: EFT-safe CPU mode for EVERY collected test file,
including the ``docs/NUMERICS.md`` doctests (which import jax outside
``tests/``, where ``tests/conftest.py`` does not apply).

XLA:CPU's LLVM backend on AVX2+ contracts mul+add into FMA inside fusions,
breaking the paper's error-free transformations — the flag must be set
before the first jax import (see ``core/selfcheck.py``; the 2006 GPUs had
no FMA either, so this is also the faithful hardware model)."""
import os

_flags = os.environ.get("XLA_FLAGS", "")
if "--xla_cpu_max_isa" not in _flags:
    os.environ["XLA_FLAGS"] = ("--xla_cpu_max_isa=SSE4_2 " + _flags).strip()
