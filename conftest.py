"""Repo-root conftest: EFT-safe CPU mode for EVERY collected test file,
including the ``docs/NUMERICS.md`` doctests (which import jax outside
``tests/``, where ``tests/conftest.py`` does not apply).

XLA:CPU's LLVM backend on AVX2+ contracts mul+add into FMA inside fusions,
breaking the paper's error-free transformations — the flag must be set
before the first jax import (see ``core/selfcheck.py``; the 2006 GPUs had
no FMA either, so this is also the faithful hardware model)."""
import os

import pytest

_flags = os.environ.get("XLA_FLAGS", "")
if "--xla_cpu_max_isa" not in _flags:
    os.environ["XLA_FLAGS"] = ("--xla_cpu_max_isa=SSE4_2 " + _flags).strip()

# one code path for CI quick sweeps and local full-grid runs: the budget
# option feeds the ``sweep_budget`` fixture, and ``slow_sweep``-marked
# exhaustive arms only run when the budget says the caller means it
FULL_SWEEP_BUDGET = 1 << 22


def pytest_addoption(parser):
    parser.addoption(
        "--sweep-budget", type=int, default=1 << 16,
        help="points per seam for the repro.verify sweeps "
             f"(>= {FULL_SWEEP_BUDGET} also enables slow_sweep tests)")


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow_sweep: exhaustive full-grid sweep arms; skipped unless "
        f"--sweep-budget >= {FULL_SWEEP_BUDGET}")


def pytest_collection_modifyitems(config, items):
    budget = config.getoption("--sweep-budget")
    if budget >= FULL_SWEEP_BUDGET:
        return
    skip = pytest.mark.skip(
        reason=f"slow_sweep needs --sweep-budget >= {FULL_SWEEP_BUDGET} "
               f"(got {budget})")
    for item in items:
        if "slow_sweep" in item.keywords:
            item.add_marker(skip)


@pytest.fixture(scope="session")
def sweep_budget(request):
    return request.config.getoption("--sweep-budget")
