"""Beyond-paper table: continuous-batching serving vs sequential decode.

The serving subsystem (``repro.serve``) wraps the fused FF flash-attention
op in a production decode loop: paged FF KV cache, continuous batching
(join/evict between decode steps), and FF ``token_logprob`` scoring as the
accuracy-critical tier.  This table measures the two claims the subsystem
ships with:

  throughput — tokens/sec over a fixed mixed-length request set:
    arm ``greedy``     — the literal sequential baseline: one
                         :func:`repro.train.serve_step.greedy_generate`
                         call per request, as a library user would write
                         it (each call builds fresh jit closures, so the
                         per-request retrace cost is part of the arm —
                         that IS the naive cost).  The >=3x gate compares
                         against this arm.
    arm ``sequential_warm`` — honesty row: the same sequential loop with
                         the prefill/decode jits built ONCE and reused,
                         i.e. the best a batch-of-1 loop can do.  The
                         engine's speedup vs this arm is the part that
                         comes from batching rather than from caching.
    arm ``engine B=k`` — :class:`repro.serve.ServeEngine` at batch k,
                         timed on a warmed instance (page-parity
                         ``kv_mode="bf16"`` plus one f32-page row).

  accuracy — every engine token is scored by ``token_logprob_ff`` (full
    vocab-LSE chain in float-float).  The gate recomputes each score from
    the engine's own logits path with a numpy f64 oracle and requires the
    worst relative error <= 2^-40 (the f32-returning score floors at
    ~2^-24 — recorded alongside for contrast).  Token parity vs the
    greedy baseline is asserted for every request.

Modes:
  python -m benchmarks.table_serving            # full table (16 requests)
  python -m benchmarks.table_serving --quick    # CI: 8 requests, B in {2,8}
  python -m benchmarks.table_serving --guard-overhead
      # additionally gate the ff.guard(mode="check") probe cost at B=8:
      # min-of-3 paired runs vs guard="off", <= 5% tokens/s overhead
  python -m benchmarks.table_serving --snapshot-overhead
      # additionally gate the crash-safety cost at B=8: engine with a
      # write-ahead journal + async snapshot every 8 decode steps vs the
      # same engine with durability off, min-of-3 paired runs, <= 5%
      # tokens/s overhead; also measures restore_to_first_token_s (warm
      # restart from the snapshot until the first post-restore token is
      # synced — includes jit re-compile, the honest restart cost)
  python -m benchmarks.table_serving --obs-overhead
      # additionally gate the observability cost at B=8: engine with the
      # full repro.obs stack on (per-request Chrome spans, latency
      # histograms, per-step gauges, obs.enable() profiler annotations)
      # vs the default engine, min-of-3 paired runs, <= 5% tokens/s
      # overhead (docs/DESIGN_observability.md)
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List, Optional, Sequence

_flags = os.environ.get("XLA_FLAGS", "")
if "--xla_cpu_max_isa" not in _flags:
    os.environ["XLA_FLAGS"] = ("--xla_cpu_max_isa=SSE4_2 " + _flags).strip()

import numpy as np
import jax
import jax.numpy as jnp

import repro.ff as ff
from repro.models import init_cache, init_params
from repro.models.config import ModelConfig
from repro.train.serve_step import (greedy_generate, make_decode_step,
                                    make_prefill_step, token_logprob,
                                    token_logprob_ff)
from repro.serve import Request, ServeEngine

#: serving accuracy contract: FF token logprob vs the f64 oracle
LOGPROB_TOL = 2.0 ** -40
#: throughput contract: engine at batch>=8 vs the sequential greedy arm
SPEEDUP_GATE = 3.0
GATE_BATCH = 8
#: robustness contract: ff.guard(mode="check") probe overhead at B=8
#: (docs/DESIGN_robustness.md §5) — <= 5% tokens/s vs guard="off"
GUARD_OVERHEAD_GATE = 1.05
#: crash-safety contract: WAL + async snapshot every SNAPSHOT_EVERY decode
#: steps at B=8 (docs/DESIGN_robustness.md §6) — <= 5% tokens/s vs off
SNAPSHOT_OVERHEAD_GATE = 1.05
SNAPSHOT_EVERY = 8
#: observability contract: full repro.obs instrumentation at B=8
#: (docs/DESIGN_observability.md §5) — <= 5% tokens/s vs obs off
OBS_OVERHEAD_GATE = 1.05

BENCH_CFG = dict(name="serve-bench", family="dense", num_layers=4,
                 d_model=256, num_heads=8, num_kv_heads=4, d_ff=1024,
                 vocab_size=4096, max_seq_len=128, compute_dtype="float32")


def _requests(rng: np.random.Generator, n: int, max_new: int,
              vocab: int) -> List[Request]:
    lens = rng.integers(8, 49, size=n)
    return [Request(uid=i,
                    prompt=rng.integers(1, vocab, size=int(l)).astype(np.int32),
                    max_new=max_new)
            for i, l in enumerate(lens)]


# --------------------------------------------------------------------------
# arms
# --------------------------------------------------------------------------

def _run_greedy(params, cfg, reqs, cache_len) -> Dict:
    """One greedy_generate call per request — fresh jit closures per call
    (the naive sequential cost a library user pays)."""
    outs = {}
    t0 = time.perf_counter()
    for r in reqs:
        toks = greedy_generate(params, cfg, jnp.asarray(r.prompt[None]),
                               r.max_new, cache_len)
        outs[r.uid] = np.asarray(toks[0])
    dt = time.perf_counter() - t0
    return {"tokens": outs, "seconds": dt,
            "count": sum(len(t) for t in outs.values())}


def _run_sequential_warm(params, cfg, reqs, cache_len) -> Dict:
    """Sequential loop with the prefill/decode jits built once."""
    pf = jax.jit(make_prefill_step(cfg))
    dc = jax.jit(make_decode_step(cfg))

    def one(r: Request) -> np.ndarray:
        cache = init_cache(cfg, 1, cache_len)
        logits, cache = pf(params, {"tokens": jnp.asarray(r.prompt[None])},
                           cache)
        toks = [jnp.argmax(logits, -1).astype(jnp.int32)]
        for t in range(r.max_new - 1):
            logits, cache = dc(params, toks[-1][:, None],
                               jnp.int32(len(r.prompt) + t), cache)
            toks.append(jnp.argmax(logits, -1).astype(jnp.int32))
        jax.block_until_ready(toks[-1])
        return np.asarray(jnp.concatenate(toks))

    for r in reqs:        # compile every prompt-length's prefill off-clock
        one(r)
    t0 = time.perf_counter()
    outs = {r.uid: one(r) for r in reqs}
    dt = time.perf_counter() - t0
    return {"tokens": outs, "seconds": dt,
            "count": sum(len(t) for t in outs.values())}


def _run_engine(params, cfg, reqs, *, batch, cache_len, kv_mode,
                guard: str = "off", snapshot_dir: Optional[str] = None,
                snapshot_every: Optional[int] = None,
                instrument: bool = False) -> Dict:
    journal = (os.path.join(snapshot_dir, "wal.jsonl")
               if snapshot_dir else None)
    kwargs = {}
    if instrument:
        from repro import obs
        kwargs["obs"] = obs.Observer()
    eng = ServeEngine(params, cfg, max_batch=batch, page_size=16,
                      max_ctx=cache_len, kv_mode=kv_mode, guard=guard,
                      journal=journal, **kwargs)
    for r in reqs:
        eng.submit(r)
    eng.run()                                      # compile outside the clock
    eng.results = {}
    for r in reqs:
        eng.submit(r)
    if instrument:
        from repro import obs
        with obs.enable():       # profiler annotations on, like production
            t0 = time.perf_counter()
            res = eng.run(snapshot_dir=snapshot_dir,
                          snapshot_every=snapshot_every)
            dt = time.perf_counter() - t0
    else:
        t0 = time.perf_counter()
        res = eng.run(snapshot_dir=snapshot_dir,
                      snapshot_every=snapshot_every)
        dt = time.perf_counter() - t0
    out = {"tokens": {u: r.tokens for u, r in res.items()},
           "results": res, "seconds": dt,
           "count": sum(len(r.tokens) for r in res.values())}
    if instrument:
        out["observer"] = eng.obs
    return out


# --------------------------------------------------------------------------
# accuracy gate: FF token logprob vs the f64 oracle, on REAL logits
# --------------------------------------------------------------------------

def _logprob_accuracy(params, cfg, reqs, cache_len) -> Dict:
    """Score the first decode logits of each request with both tiers and
    compare against the exact f64 log-softmax oracle."""
    pf = jax.jit(make_prefill_step(cfg))
    worst_ff, worst_f32 = 0.0, 0.0
    for r in reqs:
        cache = init_cache(cfg, 1, cache_len)
        logits, _ = pf(params, {"tokens": jnp.asarray(r.prompt[None])},
                       cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        s_ff = token_logprob_ff(logits, tok)
        s32 = token_logprob(logits, tok)
        lg64 = np.asarray(logits, np.float64)
        m = lg64.max(-1, keepdims=True)
        lse = np.log(np.exp(lg64 - m).sum(-1)) + m[:, 0]
        ref = lg64[np.arange(lg64.shape[0]), np.asarray(tok)] - lse
        got = np.asarray(s_ff.hi, np.float64) + np.asarray(s_ff.lo, np.float64)
        den = np.maximum(np.abs(ref), 1e-30)
        worst_ff = max(worst_ff, float(np.max(np.abs(got - ref) / den)))
        worst_f32 = max(worst_f32, float(np.max(
            np.abs(np.asarray(s32, np.float64) - ref) / den)))
    return {"ff_logprob_max_rel_err": worst_ff,
            "f32_logprob_max_rel_err": worst_f32,
            "tol": LOGPROB_TOL}


# --------------------------------------------------------------------------

def _guard_overhead_arms(params, cfg, reqs, *, batch, cache_len,
                         reps: int) -> tuple:
    """Interleaved min-of-``reps`` timing of guard="off" vs guard="check"
    at the gate batch (bf16 pages).  Interleaving means a load spike hits
    both arms alike; min-of-reps discards one-off stalls."""
    best: Dict[str, Dict] = {}
    for _ in range(max(1, reps)):
        for mode in ("off", "check"):
            r = _run_engine(params, cfg, reqs, batch=batch,
                            cache_len=cache_len, kv_mode="bf16", guard=mode)
            if mode not in best or r["seconds"] < best[mode]["seconds"]:
                best[mode] = r
    return best["off"], best["check"]


def _obs_overhead_arms(params, cfg, reqs, *, batch, cache_len,
                       reps: int) -> tuple:
    """Interleaved min-of-``reps`` timing of the default engine vs one
    with the full observability stack on: a dedicated ``obs.Observer``
    (per-request Chrome spans, latency histograms, per-step gauges) plus
    the ``obs.enable()`` profiler-annotation scope.  Span recording is
    host-side list appends + perf_counter reads per lifecycle event —
    the gate proves that stays under 5% of tokens/s at the gate batch."""
    best: Dict[str, Dict] = {}
    for _ in range(max(1, reps)):
        for mode in ("off", "obs"):
            r = _run_engine(params, cfg, reqs, batch=batch,
                            cache_len=cache_len, kv_mode="bf16",
                            instrument=(mode == "obs"))
            if mode not in best or r["seconds"] < best[mode]["seconds"]:
                best[mode] = r
    return best["off"], best["obs"]


def _snapshot_overhead_arms(params, cfg, reqs, *, batch, cache_len,
                            reps: int) -> tuple:
    """Interleaved min-of-``reps`` timing of durability OFF vs the full
    crash-safety path (fsync'd write-ahead journal + async CRC32'd
    snapshot every SNAPSHOT_EVERY decode steps) at the gate batch.  Each
    snapshot rep writes into a fresh temp directory so retention GC cost
    is identical across reps."""
    import shutil
    import tempfile
    best: Dict[str, Dict] = {}
    for _ in range(max(1, reps)):
        for mode in ("off", "snap"):
            if mode == "snap":
                d = tempfile.mkdtemp(prefix="serve-snap-bench-")
                try:
                    r = _run_engine(params, cfg, reqs, batch=batch,
                                    cache_len=cache_len, kv_mode="bf16",
                                    snapshot_dir=d,
                                    snapshot_every=SNAPSHOT_EVERY)
                finally:
                    shutil.rmtree(d, ignore_errors=True)
            else:
                r = _run_engine(params, cfg, reqs, batch=batch,
                                cache_len=cache_len, kv_mode="bf16")
            if mode not in best or r["seconds"] < best[mode]["seconds"]:
                best[mode] = r
    return best["off"], best["snap"]


def _restore_to_first_token(params, cfg, reqs, *, batch, cache_len) -> float:
    """Warm-restart latency: run a few decode steps, snapshot, then time
    ``resume_engine`` (verified checkpoint load + KV/slot rebuild + jit
    re-compile in the fresh process's stead) until the FIRST post-restore
    token is synced to the host.  Compile cost is deliberately on the
    clock — it IS the restart cost a crashed server pays."""
    import shutil
    import tempfile
    from repro.serve import resume_engine
    d = tempfile.mkdtemp(prefix="serve-restore-bench-")
    try:
        snapdir = os.path.join(d, "snap")
        wal = os.path.join(d, "wal.jsonl")
        eng = ServeEngine(params, cfg, max_batch=batch, page_size=16,
                          max_ctx=cache_len, kv_mode="bf16", journal=wal)
        for r in reqs:
            eng.submit(r)
        for _ in range(6):
            if not eng.step():
                break
        eng.save_snapshot(snapdir)

        def synced(e) -> int:
            return (sum(len(s["tokens"]) for s in e._slots if s is not None)
                    + sum(len(r.tokens) for r in e.results.values()))

        t0 = time.perf_counter()
        eng2 = resume_engine(params, cfg, snapdir, journal=wal,
                             max_batch=batch, max_ctx=cache_len,
                             page_size=16, kv_mode="bf16")
        n0 = synced(eng2)
        while eng2.step():
            eng2._flush()
            if synced(eng2) > n0:
                break
        return time.perf_counter() - t0
    finally:
        shutil.rmtree(d, ignore_errors=True)


def run(*, num_requests: int = 16, max_new: int = 24,
        batches: Sequence[int] = (2, 4, 8), cache_len: int = 80,
        guard_reps: int = 1, snapshot_reps: int = 0, obs_reps: int = 0):
    cfg = ModelConfig(**BENCH_CFG)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = _requests(rng, num_requests, max_new, cfg.vocab_size)

    greedy = _run_greedy(params, cfg, reqs, cache_len)
    warm = _run_sequential_warm(params, cfg, reqs, cache_len)
    tps_greedy = greedy["count"] / greedy["seconds"]
    tps_warm = warm["count"] / warm["seconds"]

    rows: List[Dict] = [
        {"arm": "greedy", "batch": 1, "kv_mode": "bf16",
         "tokens": greedy["count"], "seconds": greedy["seconds"],
         "tokens_per_s": tps_greedy, "speedup_vs_greedy": 1.0,
         "speedup_vs_warm": tps_greedy / tps_warm},
        {"arm": "sequential_warm", "batch": 1, "kv_mode": "bf16",
         "tokens": warm["count"], "seconds": warm["seconds"],
         "tokens_per_s": tps_warm, "speedup_vs_greedy": tps_warm / tps_greedy,
         "speedup_vs_warm": 1.0},
    ]
    parity_failures: List[str] = []
    engine_arms = [(b, "bf16") for b in batches] + [(max(batches), "f32")]
    for batch, kv_mode in engine_arms:
        eng = _run_engine(params, cfg, reqs, batch=batch,
                          cache_len=cache_len, kv_mode=kv_mode)
        tps = eng["count"] / eng["seconds"]
        rows.append({"arm": "engine", "batch": batch, "kv_mode": kv_mode,
                     "tokens": eng["count"], "seconds": eng["seconds"],
                     "tokens_per_s": tps,
                     "speedup_vs_greedy": tps / tps_greedy,
                     "speedup_vs_warm": tps / tps_warm})
        if kv_mode == "bf16":    # page parity mode: token-for-token greedy
            for r in reqs:
                if not np.array_equal(eng["tokens"][r.uid],
                                      greedy["tokens"][r.uid]):
                    parity_failures.append(
                        f"engine B={batch} uid={r.uid}: tokens diverge "
                        f"from greedy_generate")

    # guard-overhead arm: the same B=GATE_BATCH bf16 engine with the
    # per-step health probe compiled in (mode="check" — observe, don't
    # degrade).  Paired min-of-`guard_reps` timing against a fresh
    # guard="off" engine damps scheduler noise for the <=5% gate.
    off_best, guarded = _guard_overhead_arms(
        params, cfg, reqs, batch=max(batches), cache_len=cache_len,
        reps=guard_reps)
    tps_off = off_best["count"] / off_best["seconds"]
    tps_guard = guarded["count"] / guarded["seconds"]
    rows.append({"arm": "engine_guarded", "batch": max(batches),
                 "kv_mode": "bf16", "tokens": guarded["count"],
                 "seconds": guarded["seconds"], "tokens_per_s": tps_guard,
                 "speedup_vs_greedy": tps_guard / tps_greedy,
                 "speedup_vs_warm": tps_guard / tps_warm,
                 "guard_overhead": tps_off / tps_guard})
    for r in reqs:           # check mode must not change a single token
        if not np.array_equal(guarded["tokens"][r.uid],
                              greedy["tokens"][r.uid]):
            parity_failures.append(
                f"engine_guarded B={max(batches)} uid={r.uid}: tokens "
                f"diverge from greedy_generate")

    # crash-safety overhead arm: the same B=GATE_BATCH bf16 engine with
    # the write-ahead journal + async snapshot every SNAPSHOT_EVERY decode
    # steps (docs/DESIGN_robustness.md §6).  Paired min-of-`snapshot_reps`
    # timing vs a durability-off engine gates the <=5% cost; the restore
    # probe times resume_engine until the first post-restore synced token.
    if snapshot_reps:
        off_best, snapped = _snapshot_overhead_arms(
            params, cfg, reqs, batch=max(batches), cache_len=cache_len,
            reps=snapshot_reps)
        tps_off = off_best["count"] / off_best["seconds"]
        tps_snap = snapped["count"] / snapped["seconds"]
        restore_s = _restore_to_first_token(
            params, cfg, reqs, batch=max(batches), cache_len=cache_len)
        rows.append({"arm": "engine_snapshot", "batch": max(batches),
                     "kv_mode": "bf16", "tokens": snapped["count"],
                     "seconds": snapped["seconds"],
                     "tokens_per_s": tps_snap,
                     "speedup_vs_greedy": tps_snap / tps_greedy,
                     "speedup_vs_warm": tps_snap / tps_warm,
                     "snapshot_every": SNAPSHOT_EVERY,
                     "snapshot_overhead": tps_off / tps_snap,
                     "restore_to_first_token_s": restore_s})
        for r in reqs:       # durability must not change a single token
            if not np.array_equal(snapped["tokens"][r.uid],
                                  greedy["tokens"][r.uid]):
                parity_failures.append(
                    f"engine_snapshot B={max(batches)} uid={r.uid}: tokens "
                    f"diverge from greedy_generate")

    # observability overhead arm: the same B=GATE_BATCH bf16 engine with
    # the full repro.obs stack on (dedicated Observer + obs.enable()
    # profiler scope) paired min-of-`obs_reps` against the default
    # engine.  A sanity assert confirms the instrumented run actually
    # recorded one request span per request — an accidentally-dark
    # observer would make the overhead gate vacuous.
    if obs_reps:
        off_best, observed = _obs_overhead_arms(
            params, cfg, reqs, batch=max(batches), cache_len=cache_len,
            reps=obs_reps)
        tps_off = off_best["count"] / off_best["seconds"]
        tps_obs = observed["count"] / observed["seconds"]
        structure = observed["observer"].trace.span_structure()
        n_req_spans = sum(1 for _, name, _ in structure if name == "request")
        rows.append({"arm": "engine_obs", "batch": max(batches),
                     "kv_mode": "bf16", "tokens": observed["count"],
                     "seconds": observed["seconds"],
                     "tokens_per_s": tps_obs,
                     "speedup_vs_greedy": tps_obs / tps_greedy,
                     "speedup_vs_warm": tps_obs / tps_warm,
                     "obs_overhead": tps_off / tps_obs,
                     "request_spans": n_req_spans})
        if n_req_spans < len(reqs):
            parity_failures.append(
                f"engine_obs B={max(batches)}: only {n_req_spans} request "
                f"spans recorded for {len(reqs)} requests")
        for r in reqs:       # instrumentation must not change a token
            if not np.array_equal(observed["tokens"][r.uid],
                                  greedy["tokens"][r.uid]):
                parity_failures.append(
                    f"engine_obs B={max(batches)} uid={r.uid}: tokens "
                    f"diverge from greedy_generate")

    acc = _logprob_accuracy(params, cfg, reqs, cache_len)
    return rows, acc, parity_failures


def main(argv: Optional[Sequence[str]] = None,
         out_json: str = "BENCH_serving.json"):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI mode: 8 requests, batches {2, 8}")
    ap.add_argument("--requests", type=int, default=0,
                    help="override request count")
    ap.add_argument("--max-new", type=int, default=0)
    ap.add_argument("--guard-overhead", action="store_true",
                    help="gate ff.guard(mode='check') probe overhead at "
                         f"B={GATE_BATCH} (<= {GUARD_OVERHEAD_GATE:.2f}x "
                         "tokens/s vs guard='off', min-of-3 paired runs)")
    ap.add_argument("--snapshot-overhead", action="store_true",
                    help="gate the crash-safety cost (WAL + async snapshot "
                         f"every {SNAPSHOT_EVERY} decode steps) at "
                         f"B={GATE_BATCH} (<= {SNAPSHOT_OVERHEAD_GATE:.2f}x "
                         "tokens/s vs durability off, min-of-3 paired "
                         "runs) and record restore_to_first_token_s")
    ap.add_argument("--obs-overhead", action="store_true",
                    help="gate the full repro.obs instrumentation cost at "
                         f"B={GATE_BATCH} (<= {OBS_OVERHEAD_GATE:.2f}x "
                         "tokens/s vs the default engine, min-of-3 paired "
                         "runs)")
    ap.add_argument("--out", type=str, default=out_json)
    args = ap.parse_args([] if argv is None else argv)

    n = args.requests or (8 if args.quick else 16)
    max_new = args.max_new or (16 if args.quick else 24)
    batches = (2, GATE_BATCH) if args.quick else (2, 4, GATE_BATCH)

    rows, acc, parity_failures = run(
        num_requests=n, max_new=max_new, batches=batches,
        guard_reps=3 if args.guard_overhead else 1,
        snapshot_reps=3 if args.snapshot_overhead else 0,
        obs_reps=3 if args.obs_overhead else 0)

    print("serving: arm,batch,kv_mode,tok/s,vs_greedy,vs_warm")
    for r in rows:
        extra = (f",guard_overhead={r['guard_overhead']:.3f}x"
                 if "guard_overhead" in r else "")
        if "snapshot_overhead" in r:
            extra += (f",snapshot_overhead={r['snapshot_overhead']:.3f}x,"
                      f"restore={r['restore_to_first_token_s']:.2f}s")
        if "obs_overhead" in r:
            extra += f",obs_overhead={r['obs_overhead']:.3f}x"
        print(f"{r['arm']},{r['batch']},{r['kv_mode']},"
              f"{r['tokens_per_s']:.1f},{r['speedup_vs_greedy']:.2f}x,"
              f"{r['speedup_vs_warm']:.2f}x{extra}")
    print(f"ff logprob max rel err vs f64: {acc['ff_logprob_max_rel_err']:.3e}"
          f" (= 2^{np.log2(max(acc['ff_logprob_max_rel_err'], 1e-300)):.1f},"
          f" tol 2^-40); f32 tier: {acc['f32_logprob_max_rel_err']:.3e}")

    payload = {
        "bench": "serving",
        "backend": ff.backend(),
        "jax": jax.__version__,
        "config": BENCH_CFG,
        "num_requests": n,
        "max_new": max_new,
        "accuracy": acc,
        "rows": rows,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out} (backend={payload['backend']})")

    failures = list(parity_failures)
    if acc["ff_logprob_max_rel_err"] > LOGPROB_TOL:
        failures.append(
            f"FF token logprob err {acc['ff_logprob_max_rel_err']:.3e} "
            f"exceeds 2^-40")
    gate_rows = [r for r in rows if r["arm"] == "engine"
                 and r["batch"] >= GATE_BATCH and r["kv_mode"] == "bf16"]
    if not gate_rows:
        failures.append(f"no engine row at batch >= {GATE_BATCH} to gate")
    for r in gate_rows:
        if r["speedup_vs_greedy"] < SPEEDUP_GATE:
            failures.append(
                f"engine B={r['batch']} speedup {r['speedup_vs_greedy']:.2f}x"
                f" < {SPEEDUP_GATE}x vs sequential greedy_generate")
    if args.guard_overhead:
        g = next(r for r in rows if r["arm"] == "engine_guarded")
        if g["guard_overhead"] > GUARD_OVERHEAD_GATE:
            failures.append(
                f"guard='check' overhead {g['guard_overhead']:.3f}x at "
                f"B={g['batch']} exceeds {GUARD_OVERHEAD_GATE:.2f}x")
    if args.snapshot_overhead:
        s = next(r for r in rows if r["arm"] == "engine_snapshot")
        if s["snapshot_overhead"] > SNAPSHOT_OVERHEAD_GATE:
            failures.append(
                f"snapshot_every={s['snapshot_every']} overhead "
                f"{s['snapshot_overhead']:.3f}x at B={s['batch']} exceeds "
                f"{SNAPSHOT_OVERHEAD_GATE:.2f}x")
    if args.obs_overhead:
        o = next(r for r in rows if r["arm"] == "engine_obs")
        if o["obs_overhead"] > OBS_OVERHEAD_GATE:
            failures.append(
                f"obs instrumentation overhead {o['obs_overhead']:.3f}x at "
                f"B={o['batch']} exceeds {OBS_OVERHEAD_GATE:.2f}x")
    if failures:
        print("SERVING GATE FAILURES:")
        for f_ in failures:
            print(" ", f_)
        sys.exit(1)
    print(f"serving gates OK (>= {SPEEDUP_GATE}x at B>={GATE_BATCH}, "
          f"logprob <= 2^-40, token parity)")
    return rows


if __name__ == "__main__":
    main(sys.argv[1:])
