"""Paper Tables 3/4 analogue: float-float operator timings vs native f32.

The paper timed Add/Mul/Mad vs Add12/Mul12/Add22/Mul22 on data sizes
4096..1048576, normalized to Add@4096, on GPU (Table 3) and CPU (Table 4).
Our analogue on this container:

  * "compiled" arm (Table 3 analogue): jitted JAX on the CPU backend —
    vectorized, fused, the stream-processor-like regime;
  * "eager" arm (Table 4 analogue): op-by-op dispatch — the
    interpreter-overhead regime the paper's CPU numbers lived in.

The paper's qualitative claims to reproduce:
  T3-a: Add12 costs ~= basic ops on the compiled arm (fusion hides the
        3 extra flops);
  T3-b: Add22/Mul22 cost ~<= 2x basic ops on the compiled arm at size
        >= 256k (paper: 23.9/24.6 vs 10.6 at 1M -> ~2.3x);
  T3-c: the large/small data-set time ratio is far smaller for the
        compiled arm than the eager arm (paper: 25 vs 3000).
"""

from __future__ import annotations

import time
from typing import Callable, Dict, List

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import FF, add12, add22, mul12, mul22

SIZES = (4096, 16384, 65536, 262144, 1048576)


def _timeit(fn: Callable, *args, reps: int = 30, warmup: int = 5) -> float:
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def _ops(compiled: bool):
    def mk(f):
        return jax.jit(f) if compiled else f

    return {
        "Add": mk(lambda a, b: a + b),
        "Mul": mk(lambda a, b: a * b),
        "Mad": mk(lambda a, b: a * b + a),
        "Add12": mk(lambda a, b: add12(a, b).astuple()),
        "Mul12": mk(lambda a, b: mul12(a, b).astuple()),
        "Add22": mk(lambda ah, al, bh, bl:
                    add22(FF(ah, al), FF(bh, bl)).astuple()),
        "Mul22": mk(lambda ah, al, bh, bl:
                    mul22(FF(ah, al), FF(bh, bl)).astuple()),
    }


def run(reps: int = 30) -> List[Dict]:
    rng = np.random.default_rng(0)
    rows = []
    for compiled in (True, False):
        ops = _ops(compiled)
        base = None
        for n in SIZES:
            a = jnp.asarray(rng.standard_normal(n).astype(np.float32))
            b = jnp.asarray(rng.standard_normal(n).astype(np.float32))
            al = jnp.asarray((rng.standard_normal(n) * 1e-8).astype(np.float32))
            bl = jnp.asarray((rng.standard_normal(n) * 1e-8).astype(np.float32))
            row = {"arm": "compiled" if compiled else "eager", "size": n}
            for name, f in ops.items():
                args = (a, al, b, bl) if name in ("Add22", "Mul22") else (a, b)
                r = reps if compiled else max(reps // 5, 3)
                t = _timeit(f, *args, reps=r)
                row[name] = t
            if base is None:
                base = row["Add"]
            for name in ops:
                row[name + "_norm"] = row[name] / base
            rows.append(row)
    return rows


def main():
    rows = run()
    print("table3_4_timing: name,us_per_call,derived")
    for row in rows:
        for op in ("Add", "Mul", "Mad", "Add12", "Mul12", "Add22", "Mul22"):
            print(f"{row['arm']}_{op}_{row['size']},"
                  f"{row[op]*1e6:.2f},norm={row[op + '_norm']:.2f}")
    _claims(rows)


def _claims(rows):
    comp = {r["size"]: r for r in rows if r["arm"] == "compiled"}
    eag = {r["size"]: r for r in rows if r["arm"] == "eager"}
    big, small = max(SIZES), min(SIZES)
    c_add12 = comp[big]["Add12"] / comp[big]["Add"]
    c_ff = max(comp[big]["Add22"], comp[big]["Mul22"]) / comp[big]["Add"]
    ratio_c = comp[big]["Add"] / comp[small]["Add"]
    ratio_e = eag[big]["Add"] / eag[small]["Add"]
    print(f"claim_T3a_add12_vs_add,{c_add12:.2f},paper<=1.2x")
    print(f"claim_T3b_ff_vs_add,{c_ff:.2f},paper~2.3x")
    print(f"claim_T3c_scale_ratio_compiled,{ratio_c:.1f},paper=25(GPU)")
    print(f"claim_T3c_scale_ratio_eager,{ratio_e:.1f},paper=3000(CPU)")


if __name__ == "__main__":
    main()
