"""Beyond-paper table: FF matmul path accuracy/throughput trade-off.

The 2006 paper only had elementwise operators.  The TPU-era question is:
what does each FF matmul strategy cost vs deliver?

  naive     — plain f32 matmul (control)
  ozaki     — exponent-aligned slicing: exact products AND exact in-matmul
              accumulation; n^2 MXU matmuls; beyond-paper, beats dot2
              accuracy at MXU-speed cost structure
  comp      — blocked-K compensated (MXU-dominant, the production path)
  split     — Dekker split-operand (exact products, 4 MXU passes)
  dot2      — per-element Mul12 + Dot3 cascade (paper-faithful quality)

Reports us_per_call (CPU backend; relative cost is the signal) and max
err/S vs the f64 oracle (S = |A||B| condition normalizer).
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (matmul_compensated, matmul_dot2, matmul_ozaki,
                        matmul_split)


def _timeit(fn, *args, reps=10):
    out = fn(*args)
    jax.block_until_ready(jax.tree_util.tree_leaves(out))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(jax.tree_util.tree_leaves(out))
    return (time.perf_counter() - t0) / reps


def run() -> List[Dict]:
    rng = np.random.default_rng(0)
    rows = []
    M = N = 128
    for K in (512, 4096):
        A = rng.standard_normal((M, K)).astype(np.float32)
        B = rng.standard_normal((K, N)).astype(np.float32)
        E = A.astype(np.float64) @ B.astype(np.float64)
        S = np.abs(A).astype(np.float64) @ np.abs(B).astype(np.float64)
        Aj, Bj = jnp.asarray(A), jnp.asarray(B)
        paths = {
            "naive": jax.jit(lambda a, b: a @ b),
            "comp": jax.jit(lambda a, b: matmul_compensated(a, b).astuple()),
            "split": jax.jit(lambda a, b: matmul_split(a, b).astuple()),
            "dot2": jax.jit(lambda a, b: matmul_dot2(a, b).astuple()),
            "ozaki": jax.jit(lambda a, b: matmul_ozaki(a, b).astuple()),
        }
        for name, fn in paths.items():
            t = _timeit(fn, Aj, Bj)
            out = fn(Aj, Bj)
            if name == "naive":
                got = np.asarray(out, np.float64)
            else:
                got = np.asarray(out[0], np.float64) + np.asarray(out[1], np.float64)
            err = (np.abs(got - E) / S).max()
            rows.append({"path": name, "K": K, "us": t * 1e6,
                         "log2_err": float(np.log2(max(err, 2.0**-60)))})
    return rows


def main():
    print("ffmatmul: name,us_per_call,derived")
    for r in run():
        print(f"{r['path']}_K{r['K']},{r['us']:.1f},log2err={r['log2_err']:.1f}")


if __name__ == "__main__":
    main()
