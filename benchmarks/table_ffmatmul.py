"""Beyond-paper table: FF matmul path accuracy/throughput trade-off,
measured through the unified ``repro.ff.matmul`` dispatch.

The 2006 paper only had elementwise operators.  The TPU-era question is:
what does each FF matmul strategy cost vs deliver?  Every path below is a
registered implementation of the SAME op (``ff.matmul(..., impl=...)``),
so this table doubles as a benchmark of the dispatch registry's variants
on the current backend:

  naive     — plain f32 matmul (control; not FF, not dispatched)
  ozaki     — exponent-aligned slicing: exact products AND exact in-matmul
              accumulation; n^2 MXU matmuls
  hybrid    — blocked-K compensated (MXU-dominant, the default the registry
              picks; backend-aware: compiled Pallas on TPU, jnp on CPU)
  split     — Dekker split-operand (exact products, 4 MXU passes)
  dot2      — per-element Mul12 + Dot3 cascade (paper-faithful quality)

Reports us_per_call and max err/S vs the f64 oracle (S = |A||B| condition
normalizer), and emits ``BENCH_ffmatmul.json`` so the perf trajectory is
recorded per backend across PRs.
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List

# EFT-safe CPU mode when run standalone (benchmarks/run.py sets this too;
# must precede the first jax import — see repro/core/selfcheck.py)
_flags = os.environ.get("XLA_FLAGS", "")
if "--xla_cpu_max_isa" not in _flags:
    os.environ["XLA_FLAGS"] = ("--xla_cpu_max_isa=SSE4_2 " + _flags).strip()

import numpy as np
import jax
import jax.numpy as jnp

import repro.ff as ff

IMPLS = ("hybrid", "split", "dot2", "ozaki")


def _timeit(fn, *args, reps=10):
    out = fn(*args)
    jax.block_until_ready(jax.tree_util.tree_leaves(out))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(jax.tree_util.tree_leaves(out))
    return (time.perf_counter() - t0) / reps


def run() -> List[Dict]:
    rng = np.random.default_rng(0)
    rows = []
    M = N = 128
    for K in (512, 4096):
        A = rng.standard_normal((M, K)).astype(np.float32)
        B = rng.standard_normal((K, N)).astype(np.float32)
        E = A.astype(np.float64) @ B.astype(np.float64)
        S = np.abs(A).astype(np.float64) @ np.abs(B).astype(np.float64)
        Aj, Bj = jnp.asarray(A), jnp.asarray(B)

        paths = {"naive": jax.jit(lambda a, b: a @ b)}
        for impl in IMPLS:
            paths[impl] = jax.jit(
                lambda a, b, impl=impl: ff.matmul(a, b, impl=impl).astuple())
        # the registry's own pick for this backend (what ff.matmul does
        # with no override)
        paths["dispatch_default"] = jax.jit(
            lambda a, b: ff.matmul(a, b).astuple())

        for name, fn in paths.items():
            t = _timeit(fn, Aj, Bj)
            out = fn(Aj, Bj)
            if name == "naive":
                got = np.asarray(out, np.float64)
            else:
                got = np.asarray(out[0], np.float64) + np.asarray(out[1], np.float64)
            err = (np.abs(got - E) / S).max()
            rows.append({"path": name, "K": K, "us": t * 1e6,
                         "log2_err": float(np.log2(max(err, 2.0**-60)))})
    return rows


def main(out_json: str = "BENCH_ffmatmul.json"):
    rows = run()
    print("ffmatmul: name,us_per_call,derived")
    for r in rows:
        print(f"{r['path']}_K{r['K']},{r['us']:.1f},log2err={r['log2_err']:.1f}")
    payload = {
        "bench": "ffmatmul",
        "backend": ff.backend(),
        "default_impl": ff.resolve_name("matmul"),
        "shape": {"M": 128, "N": 128, "K": [512, 4096]},
        "rows": rows,
    }
    with open(out_json, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {out_json} (backend={payload['backend']}, "
          f"default={payload['default_impl']})")
    return rows


if __name__ == "__main__":
    main()
