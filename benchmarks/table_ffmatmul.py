"""Beyond-paper table: FF matmul path accuracy/throughput trade-off,
measured through the unified ``repro.ff.matmul`` dispatch.

The 2006 paper only had elementwise operators.  The TPU-era question is:
what does each FF matmul strategy cost vs deliver?  Every path below is a
registered implementation of the SAME op (``ff.matmul(..., impl=...)``),
so this table doubles as a benchmark of the dispatch registry's variants
on the current backend:

  naive     — plain f32 matmul (control; not FF, not dispatched)
  ozaki     — exponent-aligned slicing: exact products AND exact in-chunk
              accumulation via one batched stacked GEMM (paper accuracy at
              matrix-unit speed; fused Pallas kernel on TPU)
  hybrid    — blocked-K compensated (MXU-dominant; backend-aware: compiled
              Pallas on TPU, jnp on CPU)
  split     — Dekker split-operand (exact products, 4 MXU passes)
  dot2      — per-element Mul12 + Dot3 cascade, block-vectorized over K
              (paper-faithful quality; correctness anchor)
  f64       — native dgemm rounded to FF: the accurate tier at hardware
              speed wherever the hardware HAS f64 (CPU/GPU; on TPU the
              name degrades to the fused Ozaki kernel)

Every row records what actually ran: the RESOLVED impl name and block
configuration (``dispatch_default`` rows included), plus backend and jax
version in the payload, and emits ``BENCH_ffmatmul.json`` so the perf
trajectory is recorded per backend across PRs.

Modes:
  python -m benchmarks.table_ffmatmul                       # default table
  python -m benchmarks.table_ffmatmul --ksweep 256,1024,8192
  python -m benchmarks.table_ffmatmul --blocks 256,512,1024  # block sweep
  python -m benchmarks.table_ffmatmul --check-regression BENCH_ffmatmul.json

The harness asserts that ``dispatch_default`` stays within
``DEFAULT_PARITY`` of the impl it resolves to (the block_k mis-defaulting
regression class), and ``--check-regression`` compares naive-relative
ratios against a committed baseline (machine-portable: absolute times are
not comparable across boxes, ratios are).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List, Optional, Sequence

# EFT-safe CPU mode when run standalone (benchmarks/run.py sets this too;
# must precede the first jax import — see repro/core/selfcheck.py)
_flags = os.environ.get("XLA_FLAGS", "")
if "--xla_cpu_max_isa" not in _flags:
    os.environ["XLA_FLAGS"] = ("--xla_cpu_max_isa=SSE4_2 " + _flags).strip()

import numpy as np
import jax
import jax.numpy as jnp

import repro.ff as ff

IMPLS = ("hybrid", "compensated", "split", "dot2", "ozaki", "f64")

# dispatch_default must stay within this factor of the impl it resolves to
# (same computation, same compiler — anything beyond this is a dispatch
# regression, e.g. a block-size default diverging from the impl default).
DEFAULT_PARITY = 1.25
# --check-regression: fail if any path's naive-relative ratio grew by more
REGRESSION_FACTOR = 1.3


def _time_paths(fns: Dict[str, tuple], args, reps: int = 10,
                rounds: int = 13) -> Dict[str, tuple]:
    """Per-path ``(min_s, median_s)`` via the SHARED shuffled-interleave
    min-of-rounds protocol (``repro.ff.tuning.time_interleaved`` — one
    methodology for tune and bench; its docstring explains why shuffled
    rounds and time-targeted reps are load-bearing).  50ms samples here:
    identical compiled programs were measuring 6-9% apart at 20ms samples
    on a shared 2-core box."""
    from repro.ff.tuning import time_interleaved

    names = list(fns)
    res = time_interleaved([fns[n][0] for n in names], args, reps,
                           rounds=rounds, sample_target_s=0.05,
                           rep_cap=25 * reps, min_reps=3)
    bad = [n for n, r in zip(names, res) if r is None]
    if bad:
        raise RuntimeError(f"bench paths failed to run: {bad}")
    return dict(zip(names, res))


def _err_vs_oracle(got64: np.ndarray, E: np.ndarray, S: np.ndarray) -> float:
    err = (np.abs(got64 - E) / S).max()
    return float(np.log2(max(err, 2.0 ** -60)))


def run(ks: Sequence[int] = (512, 4096), M: int = 128, N: int = 128,
        blocks: Optional[Sequence[int]] = None, reps: int = 10,
        assert_default_parity: bool = True) -> List[Dict]:
    rng = np.random.default_rng(0)
    rows: List[Dict] = []
    for K in ks:
        A = rng.standard_normal((M, K)).astype(np.float32)
        B = rng.standard_normal((K, N)).astype(np.float32)
        E = A.astype(np.float64) @ B.astype(np.float64)
        S = np.abs(A).astype(np.float64) @ np.abs(B).astype(np.float64)
        Aj, Bj = jnp.asarray(A), jnp.asarray(B)
        mkn = (M, K, N)

        # path -> (callable, resolved impl name, explicit opts)
        paths: Dict[str, tuple] = {
            "naive": (jax.jit(lambda a, b: a @ b), "naive", {})}
        for impl in IMPLS:
            fn = jax.jit(
                lambda a, b, impl=impl: ff.matmul(a, b, impl=impl).astuple())
            # explicit rows also run their tuned-best block config (the
            # dispatch layer merges it under explicit kwargs) — record it
            paths[impl] = (fn, ff.resolve_name("matmul", impl, shape=mkn),
                           ff.resolve_opts("matmul", impl, mkn))
            if blocks:
                for bk in blocks:
                    if impl in ("dot2", "f64"):
                        continue       # no K-block knob on these
                    fnb = jax.jit(lambda a, b, impl=impl, bk=bk:
                                  ff.matmul(a, b, impl=impl,
                                            block_k=bk).astuple())
                    paths[f"{impl}[bk={bk}]"] = (fnb, impl, {"block_k": bk})
        # the registry's own pick for this backend+shape (what ff.matmul
        # does with no override — tuned table consulted when present)
        paths["dispatch_default"] = (
            jax.jit(lambda a, b: ff.matmul(a, b).astuple()),
            ff.resolve_name("matmul", None, shape=mkn),
            ff.resolve_opts("matmul", ff.resolve_name("matmul", None,
                                                      shape=mkn), mkn))

        # deterministic dispatch-parity evidence: when the default resolves
        # to an explicitly-benched impl, the two jits must lower to the
        # SAME program — trace-time proof that no block-config divergence
        # exists, immune to the shared-box timing noise that makes two
        # runs of one compiled program differ by several percent
        same_program = None
        target = paths.get(paths["dispatch_default"][1])
        if target is not None:
            same_program = bool(
                paths["dispatch_default"][0].lower(Aj, Bj).as_text()
                == target[0].lower(Aj, Bj).as_text())

        times = _time_paths(paths, (Aj, Bj), reps=reps)
        for name, (fn, resolved, opts) in paths.items():
            t, t_median = times[name]
            out = fn(Aj, Bj)
            if name == "naive":
                got = np.asarray(out, np.float64)
            else:
                got = (np.asarray(out[0], np.float64)
                       + np.asarray(out[1], np.float64))
            row = {
                "path": name, "M": M, "K": K, "N": N,
                "us": t * 1e6,
                "us_median": t_median * 1e6,
                "log2_err": _err_vs_oracle(got, E, S),
                "resolved_impl": resolved,
                "block_opts": dict(opts),
                "backend": ff.backend(),
                "jax": jax.__version__,
            }
            if name == "dispatch_default" and same_program is not None:
                row["same_program_as_resolved"] = same_program
            rows.append(row)

        if assert_default_parity:
            _assert_default_parity(rows, K)
    return rows


def _assert_default_parity(rows: List[Dict], K: int) -> None:
    """dispatch_default must match the impl it resolves to (satellite of the
    block_k mis-defaulting bug: identical computation, comparable time)."""
    by_path = {r["path"]: r for r in rows if r["K"] == K}
    default = by_path.get("dispatch_default")
    target = default and by_path.get(default["resolved_impl"])
    if not (default and target):
        return
    if default.get("same_program_as_resolved"):
        return     # parity proven at trace time: identical lowered program
    # fall back to timing when the programs genuinely differ (or lowering
    # comparison was unavailable).  Explicit raise (not a bare assert):
    # this is a CI gate and must survive ``python -O``.
    ratio = default["us"] / max(target["us"], 1e-9)
    if ratio > DEFAULT_PARITY:
        raise AssertionError(
            f"dispatch_default ({default['us']:.0f}us, resolves to "
            f"{default['resolved_impl']!r}) is {ratio:.2f}x the explicit "
            f"{default['resolved_impl']} row ({target['us']:.0f}us) at K={K}: "
            f"default block config has diverged from the impl default")


def check_regression(rows: List[Dict], baseline,
                     factor: float = REGRESSION_FACTOR) -> List[str]:
    """Compare naive-relative ratios to a committed baseline (dict or
    path).  Returns a list of human-readable failures (empty = pass)."""
    if isinstance(baseline, str):
        with open(baseline) as f:
            baseline = json.load(f)
    base = baseline
    failures = []

    def ratios(rws):
        naive = {(r["M"], r["K"], r["N"]): r["us"]
                 for r in rws if r["path"] == "naive"}
        out = {}
        for r in rws:
            shape = (r["M"], r["K"], r["N"])
            if r["path"] == "naive" or shape not in naive:
                continue
            out[(r["path"],) + shape] = r["us"] / naive[shape]
        return out

    now = ratios(rows)
    then = ratios(base.get("rows", []))
    shared = sorted(set(now) & set(then))
    if not shared:
        # a gate that silently checks nothing is worse than no gate — this
        # also catches a --ksweep/--mn drift away from the baseline shapes
        return ["no overlapping (path, M, K, N) rows between this run and "
                "the baseline: the regression gate compared nothing"]
    for key in shared:
        if now[key] > then[key] * factor:
            path, M, K, N = key
            failures.append(
                f"{path} {M}x{K}x{N}: {now[key]:.1f}x naive vs baseline "
                f"{then[key]:.1f}x (allowed {factor}x growth)")
    return failures


def render_impl_matrix(payload) -> str:
    """Markdown 'choosing a matmul impl' matrix from a BENCH json payload
    (README section is generated from this; ``--render-matrix`` prints it)."""
    if isinstance(payload, str):
        with open(payload) as f:
            payload = json.load(f)
    rows = payload["rows"]
    ks = sorted({r["K"] for r in rows})
    naive = {r["K"]: r["us"] for r in rows if r["path"] == "naive"}
    paths = []
    for r in rows:
        if r["path"] not in paths and "[" not in r["path"]:
            paths.append(r["path"])
    lines = [
        "| impl | worst log2 err | "
        + " | ".join(f"cost vs naive (K={k})" for k in ks)
        + " | resolved |",
        "|---|---|" + "---|" * len(ks) + "---|",
    ]
    for p in paths:
        prs = {r["K"]: r for r in rows if r["path"] == p}
        err = max(r["log2_err"] for r in prs.values())
        costs = []
        for k in ks:
            r = prs.get(k)
            costs.append(f"{r['us'] / naive[k]:.1f}x" if r and k in naive
                         else "—")
        res = prs[ks[-1]]["resolved_impl"]
        opts = ",".join(f"{a}={b}" for a, b in
                        prs[ks[-1]]["block_opts"].items())
        res = f"`{res}`" + (f" ({opts})" if opts else "")
        lines.append(f"| `{p}` | {err:.1f} | " + " | ".join(costs)
                     + f" | {res} |")
    meta = (f"backend={payload.get('backend')}, jax={payload.get('jax')}, "
            f"M=N={payload.get('shape', {}).get('M')}")
    lines.append("")
    lines.append(f"<!-- generated by `python -m benchmarks.table_ffmatmul "
                 f"--render-matrix` from BENCH_ffmatmul.json ({meta}) -->")
    return "\n".join(lines)


def main(argv: Optional[Sequence[str]] = None,
         out_json: str = "BENCH_ffmatmul.json"):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--ksweep", type=str, default="512,4096",
                    help="comma-separated K values to bench")
    ap.add_argument("--blocks", type=str, default="",
                    help="comma-separated block_k values to sweep per impl")
    ap.add_argument("--mn", type=int, default=128, help="M=N dimension")
    ap.add_argument("--reps", type=int, default=10)
    ap.add_argument("--out", type=str, default=out_json)
    ap.add_argument("--check-regression", type=str, default="",
                    help="baseline BENCH json; exit 1 if ratios regressed")
    ap.add_argument("--render-matrix", action="store_true",
                    help="print the README impl matrix from --out and exit")
    # default to no flags so programmatic callers (benchmarks/run.py) are
    # not confused by their own sys.argv
    args = ap.parse_args([] if argv is None else argv)

    if args.render_matrix:
        print(render_impl_matrix(args.out))
        return None

    ks = tuple(int(k) for k in args.ksweep.split(",") if k)
    blocks = tuple(int(b) for b in args.blocks.split(",") if b) or None
    baseline = None
    if args.check_regression:
        # load up-front (--out may overwrite the same file) and fail HARD
        # on a missing baseline: a gate that silently checks nothing is
        # worse than no gate
        with open(args.check_regression) as f:
            baseline = json.load(f)

    rows = run(ks=ks, M=args.mn, N=args.mn, blocks=blocks, reps=args.reps)

    print("ffmatmul: path,K,us_per_call,log2_err,resolved[block_opts]")
    for r in rows:
        opts = ",".join(f"{k}={v}" for k, v in r["block_opts"].items())
        print(f"{r['path']}_K{r['K']},{r['us']:.1f},log2err="
              f"{r['log2_err']:.1f},{r['resolved_impl']}"
              f"[{opts}]")
    payload = {
        "bench": "ffmatmul",
        "backend": ff.backend(),
        "jax": jax.__version__,
        # resolution is shape-aware (tuned table): record it per benched K
        "default_impl": {
            str(K): ff.resolve_name("matmul", None, shape=(args.mn, K, args.mn))
            for K in ks},
        "shape": {"M": args.mn, "N": args.mn, "K": list(ks)},
        "rows": rows,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out} (backend={payload['backend']}, "
          f"default={payload['default_impl']})")

    if baseline is not None:
        # baseline was loaded up-front: --out may legally point at the same
        # file we are comparing against (CI overwrites the artifact)
        failures = check_regression(rows, baseline)
        if failures:
            print("PERF REGRESSION vs", args.check_regression)
            for f_ in failures:
                print(" ", f_)
            sys.exit(1)
        print(f"regression check vs {args.check_regression}: OK")
    return rows


if __name__ == "__main__":
    main(sys.argv[1:])
