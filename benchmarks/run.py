"""Benchmark harness — one function per paper table + beyond-paper tables.

Prints ``name,us_per_call,derived`` CSV per table:
  * table3/4 (timing): benchmarks.table_timing  — FF ops vs basic ops,
    compiled ('GPU') vs eager ('CPU') arms, sizes 4k..1M.
  * table5 (accuracy): benchmarks.table_accuracy — max sampled error vs
    the exact f64 oracle (2^22 vectors; --full for the paper's 2^24).
  * ffmatmul (beyond paper): FF matmul paths through the ``repro.ff``
    dispatch registry (per-backend variant selection); also emits
    ``BENCH_ffmatmul.json`` for the perf trajectory.
  * elementwise (beyond paper): fused FF expression pipelines
    (adamw/softmax/logsumexp/norm-stats chains) vs op-by-op streaming;
    emits ``BENCH_elementwise.json``.
  * math (beyond paper): the ff.math elementary-function tiers vs the
    hardware builtins vs native f64 (throughput + measured worst error);
    emits ``BENCH_math.json``.
  * optimizer (beyond paper): FF master-weight AdamW cost + the
    f32-stagnation experiment.
  * serving (beyond paper): continuous-batching ServeEngine vs the
    sequential greedy baseline + FF token-logprob accuracy gate; emits
    ``BENCH_serving.json``.

Roofline/dry-run/mesh tables are separate (they need simulated devices):
  PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes
  PYTHONPATH=src python -m benchmarks.roofline
  PYTHONPATH=src python -m benchmarks.table_distributed   # 8-device mesh
"""

import os

# EFT-safe CPU validation (see repro/core/selfcheck.py): must precede jax
_flags = os.environ.get("XLA_FLAGS", "")
if "--xla_cpu_max_isa" not in _flags:
    os.environ["XLA_FLAGS"] = ("--xla_cpu_max_isa=SSE4_2 " + _flags).strip()


def main() -> None:
    from repro.core.selfcheck import require_eft_safe
    require_eft_safe(strict=False)

    from benchmarks import (table_accuracy, table_elementwise,
                            table_ffmatmul, table_math, table_optimizer,
                            table_serving, table_timing)
    print("# paper Table 3/4 analogue — operator timings")
    table_timing.main()
    print("\n# paper Table 5 analogue — operator accuracy")
    table_accuracy.main()
    print("\n# beyond paper — FF matmul paths (repro.ff dispatch)")
    table_ffmatmul.main()
    print("\n# beyond paper — fused FF pipelines vs op-by-op streaming")
    table_elementwise.main()   # default shapes == the committed baseline's
    print("\n# beyond paper — ff.math elementary functions vs builtins")
    table_math.main()
    print("\n# beyond paper — FF master-weight optimizer")
    table_optimizer.main()
    print("\n# beyond paper — continuous-batching serving (paged FF KV)")
    table_serving.main(["--quick"])


if __name__ == "__main__":
    main()
