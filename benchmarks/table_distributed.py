"""Weak/strong scaling of the mesh-partitioned FF tier (``repro.ff.sharded``).

Runs on a simulated CPU mesh (``--xla_force_host_platform_device_count``,
default 8 devices) and emits ``BENCH_distributed.json``:

    PYTHONPATH=src python -m benchmarks.table_distributed            # full
    PYTHONPATH=src python -m benchmarks.table_distributed --quick    # CI gate

Methodology — simulated devices share the machine's physical cores, so two
numbers are reported per row and it matters which one you read:

* ``wall_ms``: the whole sharded program timed on the D-device mesh.  On
  an oversubscribed host this CANNOT show real scaling (the single-device
  baseline already multithreads across the same cores; D fake devices add
  scheduling + copy overhead), so expect wall_speedup <= 1 here.  It is
  recorded because it is the honest end-to-end cost on THIS machine and
  gates functional regressions.
* ``critical_ms = local_ms + combine_ms``: the per-device critical path —
  the measured per-shard local program (the inner impl at the (M, K/D, N)
  shard shape, run alone on one device) plus the measured *per-device
  combine compute* (a tree all-reduce costs each device ceil(log2 D)
  plane-adds per limb for ``psum``, resp. log2(D) Add22_accurate folds for
  ``tree`` — that fold chain is timed as a one-device program).  This is
  the wall time a D-device mesh with one shard per physical device would
  see, EXCLUDING interconnect transfer: a simulated mesh has no
  interconnect to measure (its "collectives" are host memcpys contending
  for the same 2 cores — neither a network model nor free), so transfer
  cost is out of scope here and the combine term charges the compute a
  real device provably pays.  ``scaled_speedup = single_ms / critical_ms``
  is the strong-scaling headline.

Why the FF tier scales SUPER-linearly in compute terms: the single-device
fast path at large K is fold-dominated (K/block_k sequential GEMM+Add22
passes over the full (M, N) output — the 3x-naive column in the README
matrix), while a K-split shard needs ONE local GEMM + renormalize and the
compensated combine replaces the serial fold chain entirely.  Sharding
removes work per device faster than 1/D.

Accuracy gates (always on): the sharded fast/accurate-class results on the
mesh must match the f64 oracle within their documented NUMERICS.md bounds
(2^-19 / 2^-44 classes) — a scaling number from a wrong result is void.
"""

import argparse
import json
import os
import sys
import time

_f = os.environ.get("XLA_FLAGS", "")
if "--xla_cpu_max_isa" not in _f:
    _f = ("--xla_cpu_max_isa=SSE4_2 " + _f).strip()
if "--xla_force_host_platform_device_count" not in _f:
    _f = (_f + " --xla_force_host_platform_device_count=8").strip()
os.environ["XLA_FLAGS"] = _f

import numpy as np                                     # noqa: E402
import jax                                             # noqa: E402
import jax.numpy as jnp                                # noqa: E402
from jax.experimental.shard_map import shard_map       # noqa: E402
from jax.sharding import Mesh, PartitionSpec as P      # noqa: E402

import repro.ff as ff                                  # noqa: E402
from repro.ff import sharded as ffsh                   # noqa: E402
from repro.ff import tuning                            # noqa: E402
from repro.core.ff import FF                           # noqa: E402

FAST_BOUND = 2.0 ** -19        # fast class ceiling (docs/NUMERICS.md)
ACC_BOUND = 2.0 ** -44         # accurate class ceiling


def _mesh(d: int) -> Mesh:
    return Mesh(np.array(jax.devices()[:d]), ("x",))


def _mesh_call(mesh, fn):
    """jit ``fn`` and enter the on_mesh scope around every call, so the
    trace (first call, inside the timing harness's warmup) sees it."""
    jfn = jax.jit(fn)

    def call(*a):
        with ff.on_mesh(mesh, axis="x"):
            return jfn(*a)
    return call


def _time(fns, args, rounds: int) -> list:
    """Shared shuffled-interleave protocol (min-of-rounds seconds/call)."""
    res = tuning.time_interleaved(fns, args, reps=1, rounds=rounds,
                                  sample_target_s=0.02, min_reps=1)
    return [r[0] if r is not None else None for r in res]


def _combine_local_probe(d, M, N, how):
    """Per-device combine COMPUTE as a one-device program (see module
    docstring): ``ceil(log2 d)`` incoming (M, N) FF partials folded into
    the local one — plane adds + a final TwoSum renormalize for ``psum``
    (what a tree all-reduce costs each device), Add22_accurate folds for
    ``tree`` (exactly the butterfly's per-device work)."""
    from repro.core import ff as core_ff
    from repro.core import transforms as T

    steps = max(int(np.ceil(np.log2(d))), 0) if d > 1 else 0
    rng = np.random.default_rng(7)
    hi = jnp.asarray(rng.standard_normal((steps + 1, M, N))
                     .astype(np.float32))
    lo = jnp.asarray((np.asarray(hi) * 1e-8).astype(np.float32))

    def body(h, l):
        if how == "psum":
            hh, ll = h[0], l[0]
            for s in range(1, steps + 1):
                hh = hh + h[s]
                ll = ll + l[s]
            s2, e = T.two_sum(hh, ll)
            return s2, e
        r = FF(h[0], l[0])
        for s in range(1, steps + 1):
            r = core_ff.add22_accurate(r, FF(h[s], l[s]))
        return r.hi, r.lo

    return jax.jit(body), (hi, lo)


def _err(R, E, S) -> float:
    return float((np.abs(np.asarray(R.to_f64()) - E) / S).max())


def bench_matmul(mode: str, M: int, K_of, N: int, devices, rounds: int,
                 oracle_at) -> list:
    """One scaling sweep.  ``K_of(d)`` gives the global K per device count
    (constant for strong scaling, 512*d-style for weak)."""
    rng = np.random.default_rng(0)
    rows = []
    for klass, acc in (("fast", False), ("accurate", True)):
        impl = "sharded_accurate" if acc else "sharded"
        # single-device baseline at each K (strong: one K; weak: per-d)
        singles = {}
        for d in devices:
            K = K_of(d)
            if K in singles:
                continue
            A = jnp.asarray(rng.standard_normal((M, K)).astype(np.float32))
            B = jnp.asarray(rng.standard_normal((K, N)).astype(np.float32))
            sfn = jax.jit(lambda a, b: ff.matmul(
                a, b, impl="tuned_accurate" if acc else None).astuple())
            (t1,) = _time([sfn], (A, B), rounds)
            singles[K] = (A, B, t1)
        for d in devices:
            K = K_of(d)
            A, B, t_single = singles[K]
            kl = K // d
            mesh = _mesh(d)
            how = "tree" if acc else "psum"
            inner = ffsh._resolve_inner("matmul", None, acc, (M, kl, N))
            wall = _mesh_call(mesh, lambda a, b, impl=impl: ff.matmul(
                a, b, impl=impl).astuple())
            local = jax.jit(lambda a, b, inner=inner: ff.matmul(
                a, b, impl=inner).astuple())
            cfn, cargs = _combine_local_probe(d, M, N, how)
            t_wall, = _time([wall], (A, B), rounds)
            t_local, = _time([local], (A[:, :kl], B[:kl]), rounds)
            t_comb, = _time([cfn], cargs, rounds)
            row = {
                "mode": mode, "op": f"matmul_{klass}", "M": M, "K": K,
                "N": N, "devices": d, "inner": inner, "combine": how,
                "single_ms": t_single * 1e3, "wall_ms": t_wall * 1e3,
                "local_ms": t_local * 1e3, "combine_ms": t_comb * 1e3,
                "critical_ms": (t_local + t_comb) * 1e3,
                "wall_speedup": t_single / t_wall,
                "scaled_speedup": t_single / (t_local + t_comb),
            }
            if (mode, d) in oracle_at:
                E = np.asarray(A, np.float64) @ np.asarray(B, np.float64)
                S = (np.abs(np.asarray(A, np.float64))
                     @ np.abs(np.asarray(B, np.float64)))
                with ff.on_mesh(mesh, axis="x"):
                    R = jax.jit(lambda a, b: ff.matmul(a, b, impl=impl))(A, B)
                e = _err(R, E, S)
                row["err_vs_oracle"] = e
                bound = ACC_BOUND if acc else FAST_BOUND
                assert e < bound, (
                    f"{klass} sharded matmul {M}x{K}x{N} on {d} devices: "
                    f"err {e:.3e} exceeds the documented {bound:.3e} bound")
            rows.append(row)
            print(f"  {row['op']:16s} {mode:6s} K={K:5d} d={d}  "
                  f"single {row['single_ms']:8.1f}ms  wall "
                  f"{row['wall_ms']:8.1f}ms  critical "
                  f"{row['critical_ms']:8.1f}ms  scaled x"
                  f"{row['scaled_speedup']:.2f}"
                  + (f"  err 2^{np.log2(row['err_vs_oracle']):.1f}"
                     if "err_vs_oracle" in row else ""))
    return rows


def bench_sum(n: int, devices, rounds: int) -> list:
    rng = np.random.default_rng(2)
    v = (rng.standard_normal(n) * 10.0 ** rng.uniform(-4, 4, n)
         ).astype(np.float32)
    x = jnp.asarray(v)
    exact = float(np.sum(v.astype(np.float64)))
    sfn = jax.jit(lambda u: ff.sum(u).astuple())
    (t1,) = _time([sfn], (x,), rounds)
    rows = []
    for d in devices:
        mesh = _mesh(d)
        wall = _mesh_call(mesh, lambda u: ff.sum(u).astuple())
        local = jax.jit(lambda u: ff.sum(u, impl="blocked").astuple())
        t_wall, = _time([wall], (x,), rounds)
        t_local, = _time([local], (x[: n // d],), rounds)
        with ff.on_mesh(mesh, axis="x"):
            s = jax.jit(lambda u: ff.sum(u))(x)
        rel = abs(float(s.to_f64()) - exact) / abs(exact)
        assert rel < 2.0 ** -40, (
            f"sharded ff.sum on {d} devices: rel err {rel:.3e} exceeds the "
            f"documented compensated bound")
        rows.append({
            "mode": "strong", "op": "sum", "n": n, "devices": d,
            "combine": "tree", "single_ms": t1 * 1e3,
            "wall_ms": t_wall * 1e3, "local_ms": t_local * 1e3,
            "combine_ms": None, "critical_ms": t_local * 1e3,
            "wall_speedup": t1 / t_wall,
            "scaled_speedup": t1 / t_local, "rel_err": rel,
        })
        print(f"  sum              strong n={n} d={d}  single {t1*1e3:8.1f}ms"
              f"  wall {t_wall*1e3:8.1f}ms  local {t_local*1e3:8.1f}ms  "
              f"scaled x{t1 / t_local:.2f}  rel {rel:.1e}")
    return rows


def check_regression(rows, baseline_path: str) -> int:
    """Ratio-based gate against a committed baseline: a row's
    scaled_speedup collapsing below baseline/1.3 fails (absolute times are
    machine-local; speedup ratios are portable)."""
    with open(baseline_path) as f:
        base = json.load(f)

    def key(r):
        return (r["mode"], r["op"], r.get("K"), r.get("n"), r["devices"])

    old = {key(r): r for r in base["rows"]}
    failures = overlap = 0
    for r in rows:
        b = old.get(key(r))
        if b is None:
            continue
        overlap += 1
        if r["scaled_speedup"] < b["scaled_speedup"] / 1.3:
            print(f"[gate] REGRESSION {key(r)}: scaled_speedup "
                  f"{r['scaled_speedup']:.2f} < baseline "
                  f"{b['scaled_speedup']:.2f}/1.3", file=sys.stderr)
            failures += 1
    if overlap == 0:
        print("[gate] FAIL: zero overlapping rows with the baseline — "
              "shape/device mismatch, the gate checked nothing",
              file=sys.stderr)
        return 1
    print(f"[gate] {overlap} rows checked vs {baseline_path}, "
          f"{failures} regressions")
    return 1 if failures else 0


def main():
    ap = argparse.ArgumentParser(
        description="FF mesh scaling bench (see module docstring)")
    ap.add_argument("--quick", action="store_true",
                    help="CI mode: 1024-class shapes, fewer rounds")
    ap.add_argument("--devices", default=None,
                    help="comma list of device counts (default 1,2,4,8)")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--out", default="BENCH_distributed.json")
    ap.add_argument("--check-regression", metavar="BASELINE")
    args = ap.parse_args()

    ndev = len(jax.devices())
    devices = ([int(x) for x in args.devices.split(",")] if args.devices
               else [d for d in (1, 2, 4, 8) if d <= ndev])
    rounds = args.rounds or (2 if args.quick else 3)
    if args.quick:
        M = N = 1024
        K_strong = 1024
        k_weak = 256
        n_sum = 1 << 20
    else:
        M = N = 4096
        K_strong = 4096
        k_weak = 512
        n_sum = 1 << 22
    dmax = max(devices)
    print(f"[distributed] backend={jax.default_backend()} devices={ndev} "
          f"(simulated; {os.cpu_count()} physical cpus) "
          f"scaling over {devices}")
    print(f"[distributed] strong scaling: matmul {M}x{K_strong}x{N}")
    rows = bench_matmul("strong", M, lambda d: K_strong, N, devices, rounds,
                        oracle_at={("strong", 1), ("strong", dmax)})
    print(f"[distributed] weak scaling: matmul {M}x({k_weak}*D)x{N}")
    rows += bench_matmul("weak", M, lambda d: k_weak * d, N, devices, rounds,
                         oracle_at={("weak", dmax)})
    print(f"[distributed] strong scaling: sum n={n_sum}")
    rows += bench_sum(n_sum, devices, rounds)

    payload = {
        "meta": {
            "backend": jax.default_backend(),
            "jax": jax.__version__,
            "devices_simulated": ndev,
            "physical_cpus": os.cpu_count(),
            "quick": bool(args.quick),
            "note": ("wall_ms is oversubscribed (simulated devices share "
                     "physical cores); critical_ms = measured per-shard "
                     "local program + measured combine = per-device wall "
                     "time on a real mesh"),
        },
        "rows": rows,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    head = [r for r in rows
            if r["mode"] == "strong" and r["devices"] == dmax
            and r["op"].startswith("matmul")]
    for r in head:
        print(f"[distributed] headline: {r['op']} {M}x{K_strong}x{N} on "
              f"{dmax} devices: scaled strong-scaling x"
              f"{r['scaled_speedup']:.2f} (wall x{r['wall_speedup']:.2f} "
              f"oversubscribed)")
    print(f"[distributed] wrote {args.out} ({len(rows)} rows)")
    if args.check_regression:
        sys.exit(check_regression(rows, args.check_regression))


if __name__ == "__main__":
    main()
