"""Roofline analysis (EXPERIMENTS.md §Roofline) from dry-run artifacts.

Hardware model (TPU v5e, per chip):
    peak bf16 compute : 197 TFLOP/s
    HBM bandwidth     : 819 GB/s
    ICI link bandwidth: ~50 GB/s

Terms (seconds per step, PER CHIP — dry-run HLO is the per-device SPMD
program, verified against a controlled sharded matmul):
    compute    = HLO_flops_per_dev / 197e12
    memory     = HLO_bytes_per_dev / 819e9
    collective = collective_bytes_per_dev / 50e9

Bottleneck = argmax(term); roofline fraction = compute / max(terms)
(1.0 = perfectly compute-bound at peak).  MODEL_FLOPS = 6·N·D (train) or
2·N_active·D (serve) + analytic attention/SSD terms; the ratio
MODEL_FLOPS/HLO_FLOPs exposes remat/dispatch waste (HLO counts the
recompute, the model-math doesn't).

Usage:
    PYTHONPATH=src python -m benchmarks.roofline --artifacts artifacts/dryrun
        [--markdown EXPERIMENTS_roofline.md]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

MESH_CHIPS = {"16x16": 256, "2x16x16": 512}


# ---------------------------------------------------------------------------
# analytic MODEL_FLOPS
# ---------------------------------------------------------------------------

def _param_counts(cfg) -> Dict[str, float]:
    """Exact param counts via eval_shape (no allocation)."""
    from repro.models import init_params

    shapes = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.random.PRNGKey(0))
    total = emb = expert = router = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        n = 1
        for s in leaf.shape:
            n *= s
        keys = [str(getattr(k, "key", "")) for k in path]
        total += n
        if "embed" in keys or "patch_proj" in keys:
            emb += n
        in_moe = ("ffn" in keys or "ffn_moe" in keys) and "shared" not in keys
        if in_moe and keys[-1] in ("w_gate", "w_up", "w_down"):
            expert += n
        if keys[-1] == "router":
            router += n
    active = total - expert
    if cfg.moe_num_experts:
        active += expert * cfg.moe_top_k / cfg.moe_num_experts
    return {"total": float(total), "embedding": float(emb),
            "expert": float(expert), "active": float(active),
            "active_nonemb": float(active - emb)}


def _attn_flops_fwd(cfg, B: int, S: int, causal: bool = True) -> float:
    """Per-token-pair attention flops (QK^T + PV), causal halves it."""
    if cfg.family == "ssm":
        return 0.0
    hd = cfg.resolved_head_dim
    if cfg.use_mla:
        hd = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    n_attn = cfg.num_layers
    if cfg.family == "hybrid":
        n_attn = cfg.num_layers // cfg.attn_every
    f = 4.0 * B * S * S * cfg.num_heads * hd * n_attn
    return f * (0.5 if causal else 1.0)


def _ssd_flops_fwd(cfg, B: int, S: int) -> float:
    if cfg.family not in ("ssm", "hybrid"):
        return 0.0
    n_ssd = cfg.num_layers
    if cfg.family == "hybrid":
        n_ssd = cfg.num_layers - cfg.num_layers // cfg.attn_every
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    Q = 256  # chunk
    # intra-chunk (quadratic in Q) + state terms
    intra = 2.0 * B * S * Q * (H * P + N)
    state = 4.0 * B * S * H * P * N
    return (intra + state) * n_ssd


def model_flops(cfg, kind: str, B: int, S: int) -> Dict[str, float]:
    counts = _param_counts(cfg)
    if kind == "train":
        tokens = B * S
        dense = 6.0 * counts["active_nonemb"] * tokens
        attn = 3.0 * (_attn_flops_fwd(cfg, B, S) + _ssd_flops_fwd(cfg, B, S))
    elif kind == "prefill":
        tokens = B * S
        dense = 2.0 * counts["active_nonemb"] * tokens
        attn = _attn_flops_fwd(cfg, B, S) + _ssd_flops_fwd(cfg, B, S)
    else:  # decode: one token attending to S cache
        tokens = B
        dense = 2.0 * counts["active_nonemb"] * tokens
        if cfg.family == "ssm":
            attn = _ssd_flops_fwd(cfg, B, 1)
        elif cfg.family == "hybrid":
            n_attn = cfg.num_layers // cfg.attn_every
            hd = cfg.resolved_head_dim
            attn = 4.0 * B * S * cfg.num_heads * hd * n_attn \
                + _ssd_flops_fwd(cfg, B, 1)
        else:
            hd = cfg.resolved_head_dim
            if cfg.use_mla:
                hd = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
            attn = 4.0 * B * S * cfg.num_heads * hd * cfg.num_layers
    return {"model_flops": dense + attn, "dense": dense, "attn": attn,
            **counts}


# ---------------------------------------------------------------------------
# roofline assembly
# ---------------------------------------------------------------------------

def analyze(rec: Dict[str, Any]) -> Optional[Dict[str, Any]]:
    if rec.get("status") != "ok":
        return None
    chips = MESH_CHIPS[rec["mesh"]]
    flops_dev = rec["cost"]["flops"]
    bytes_dev = rec["cost"]["bytes"]
    coll_dev = rec["collectives"]["total"]
    t_comp = flops_dev / PEAK_FLOPS
    t_mem = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    dominant = terms[bottleneck]
    frac = t_comp / dominant if dominant > 0 else 0.0

    from repro.configs import get_config
    cfg = get_config(rec["arch"].replace("-", "_"))
    mf = model_flops(cfg, rec["kind"], rec["global_batch"], rec["seq_len"])
    hlo_global = flops_dev * chips
    ratio = mf["model_flops"] / hlo_global if hlo_global else 0.0

    out = dict(rec)
    out.update({
        "chips": chips,
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "bottleneck": bottleneck, "roofline_fraction": frac,
        "model_flops": mf["model_flops"],
        "hlo_flops_global": hlo_global,
        "useful_ratio": ratio,
        "params_total": mf["total"], "params_active": mf["active"],
    })
    return out


def what_would_help(row: Dict[str, Any]) -> str:
    b = row["bottleneck"]
    if b == "compute":
        if row["useful_ratio"] < 0.5:
            return ("compute-bound but <50% useful flops: cut remat recompute "
                    "/ masked-block attention waste")
        return "compute-bound at high useful ratio: already near roofline"
    if b == "memory":
        return ("HBM-bound: fuse/bf16-ify the dominant streams, raise "
                "arithmetic intensity (bigger K-blocks, fewer passes)")
    return ("collective-bound: reshard to cut all-gather volume, overlap "
            "collectives with compute, or batch small transfers")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default="artifacts/dryrun")
    ap.add_argument("--json-out", default="artifacts/roofline.json")
    ap.add_argument("--markdown", default=None)
    ap.add_argument("--mesh", default="16x16",
                    help="roofline table mesh (single-pod per assignment)")
    args = ap.parse_args()

    rows, skips, fails = [], [], []
    for path in sorted(glob.glob(os.path.join(args.artifacts, "*.json"))):
        rec = json.load(open(path))
        if rec.get("status") == "skipped":
            skips.append(rec)
            continue
        if rec.get("status") != "ok":
            fails.append(rec)
            continue
        if rec["mesh"] != args.mesh:
            continue
        row = analyze(rec)
        if row:
            rows.append(row)

    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    os.makedirs(os.path.dirname(args.json_out) or ".", exist_ok=True)
    with open(args.json_out, "w") as f:
        json.dump(rows, f, indent=1, default=float)

    hdr = (f"| arch | shape | compute s | memory s | collective s | "
           f"bottleneck | roofline frac | MODEL/HLO flops |")
    sep = "|" + "---|" * 8
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"**{r['bottleneck']}** | {r['roofline_fraction']:.2f} | "
            f"{r['useful_ratio']:.2f} |")
    for s in skips:
        if s.get("mesh") == args.mesh or True:
            pass
    table = "\n".join(lines)
    print(table)
    print(f"\n{len(rows)} cells analyzed, {len(skips)} skipped, "
          f"{len(fails)} FAILED")
    for r in rows:
        print(f"- {r['arch']} x {r['shape']}: {what_would_help(r)}")
    if args.markdown:
        with open(args.markdown, "w") as f:
            f.write(table + "\n")


if __name__ == "__main__":
    main()
