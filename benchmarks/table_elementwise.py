"""Beyond-paper table: fused FF expression pipelines vs op-by-op streaming.

The source paper reports per-operator throughputs; follow-up work applying
the operators to real simulations (Collange–Daumas–Defour, cs/0703028)
shows what actually dominates: CHAINS of emulated ops, each launched as its
own pass over memory.  This table measures that directly for the hot
composite chains ``repro.ff`` now ships fused:

  arm ``unfused``   — the op-by-op dispatch path: the chain written as a
                      plain sequence of ``ff.*`` / jnp calls and executed
                      EAGERLY, so every operator is its own compiled
                      executable with a full memory round-trip — the
                      paper's one-fragment-shader-pass-per-operator
                      streaming model, and literally what the dispatch
                      layer does outside ``jax.jit``.
  arm ``fused``     — ONE dispatched composite call under one jit
                      (``ff.adamw_update`` / ``ff.softmax`` / ... — a
                      single Pallas kernel on TPU, the backend's best
                      single-launch implementation elsewhere).
  arm ``whole_jit`` — honesty row: the op-by-op chain under ONE jit, i.e.
                      what XLA's own fusion recovers without our layer.

Every row records the resolved fused impl, both times (shared
shuffled-interleave protocol, ``repro.ff.tuning.time_interleaved``), the
``speedup`` = unfused/fused, and ``max_ulp_diff`` — the worst difference
between the fused and unfused primary outputs in units of the reference's
f32 ulp (0 = bitwise; reduction chains are allowed 1, see
``docs/DESIGN_fusion.md``).  Emits ``BENCH_elementwise.json``;
``--check-regression`` compares speedups ratio-wise against a committed
baseline (machine-portable) and fails if any chain's speedup decayed by
more than ``REGRESSION_FACTOR`` (or the accuracy contract broke).

Modes:
  python -m benchmarks.table_elementwise                    # default table
  python -m benchmarks.table_elementwise --shapes 256x1024
  python -m benchmarks.table_elementwise --check-regression BENCH_elementwise.json
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Callable, Dict, List, Optional, Sequence, Tuple

_flags = os.environ.get("XLA_FLAGS", "")
if "--xla_cpu_max_isa" not in _flags:
    os.environ["XLA_FLAGS"] = ("--xla_cpu_max_isa=SSE4_2 " + _flags).strip()

import numpy as np
import jax
import jax.numpy as jnp

import repro.ff as ff
from repro.core.ff import FF

REGRESSION_FACTOR = 1.3
# reduction chains may differ from the op-by-op reference by the final
# rounding ulp (two compensated summation orders); elementwise chains by 0
ULP_TOL = {"adamw": 0.0, "axpy": 0.0, "softmax": 2.0, "logsumexp": 1.0,
           "rmsnorm_stats": 1.0, "norm_stats": 2.0}


def _ulp_diff(a: np.ndarray, b: np.ndarray) -> float:
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    return float((np.abs(a - b) / np.spacing(np.maximum(
        np.abs(b), np.float32(1e-30)))).max())


# --------------------------------------------------------------------------
# chains: each builder returns dict(args, fused, unfused, whole_jit,
#                                   resolved, primary)
# `unfused` is written as the library user would write it WITHOUT jit and
# runs eagerly — one executable per operator (do not wrap it in jax.jit or
# the arm stops measuring what it is named after).
# `primary(out)` extracts the f32 array both arms are compared on.
# --------------------------------------------------------------------------

def _mk_adamw(rng, R, C):
    sh = (R, C)
    g = jnp.asarray(rng.standard_normal(sh).astype(np.float32))
    m = jnp.asarray((rng.standard_normal(sh) * 0.1).astype(np.float32))
    v = jnp.asarray(np.abs(rng.standard_normal(sh) * 0.01).astype(np.float32))
    w = jnp.asarray(rng.standard_normal(sh).astype(np.float32))
    wlo = jnp.asarray((rng.standard_normal(sh) * 1e-8).astype(np.float32))
    lr, b1, b2 = jnp.float32(1e-3), jnp.float32(0.9), jnp.float32(0.95)
    bc1, bc2 = jnp.float32(0.1), jnp.float32(0.05)
    eps, wd = 1e-8, 0.1
    args = (g, m, v, w, wlo)

    def op_by_op(g, m, v, w, wlo):
        # the pre-fusion AdamW leaf, verbatim (~16 eager executions)
        m2 = b1 * m + (1.0 - b1) * g
        v2 = b2 * v + (1.0 - b2) * g * g
        u = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + eps)
        u = u + wd * w
        d = -lr * u
        new = ff.add(FF(w, wlo), d)
        return new.hi, new.lo, m2, v2

    def chain(g, m, v, w, wlo):
        new, m2, v2 = ff.adamw_update(g, m, v, w, wlo, lr, b1, b2, bc1, bc2,
                                      eps=eps, wd=wd)
        return new.hi, new.lo, m2, v2

    return {
        "args": args,
        "fused": jax.jit(chain),
        "unfused": op_by_op,
        "whole_jit": jax.jit(op_by_op),
        "resolved": ff.resolve_name("adamw_update", None, shape=sh),
        "primary": lambda out: out[0],
    }


def _mk_softmax(rng, R, C):
    x = jnp.asarray(rng.standard_normal((R, C)).astype(np.float32))

    def op_by_op(x):
        m = jnp.max(x, axis=-1, keepdims=True)
        e = jnp.exp(x - m)
        s = ff.sum(e, axis=-1, block=256)
        return e / s.to_f32()[..., None]

    return {
        "args": (x,),
        "fused": jax.jit(lambda x: ff.softmax(x)),
        "unfused": op_by_op,
        "whole_jit": jax.jit(op_by_op),
        "resolved": ff.resolve_name("softmax", None, shape=(R, C)),
        "primary": lambda out: out,
    }


def _mk_logsumexp(rng, R, C):
    x = jnp.asarray(rng.standard_normal((R, C)).astype(np.float32))

    def op_by_op(x):
        m = jnp.max(x, axis=-1, keepdims=True)
        e = jnp.exp(x - m)
        s = ff.sum(e, axis=-1, block=256)
        return jnp.squeeze(m, -1) + jnp.log(s.to_f32())

    return {
        "args": (x,),
        "fused": jax.jit(lambda x: ff.logsumexp(x)),
        "unfused": op_by_op,
        "whole_jit": jax.jit(op_by_op),
        "resolved": ff.resolve_name("logsumexp", None, shape=(R, C)),
        "primary": lambda out: out,
    }


def _mk_rmsnorm_stats(rng, R, C):
    x = jnp.asarray(rng.standard_normal((R, C)).astype(np.float32))

    def op_by_op(x):
        return ff.sum(x * x, axis=-1, block=128).to_f32() / C

    return {
        "args": (x,),
        "fused": jax.jit(lambda x: ff.mean_sq(x)),
        "unfused": op_by_op,
        "whole_jit": jax.jit(op_by_op),
        "resolved": ff.resolve_name("mean_sq", None, shape=(R, C)),
        "primary": lambda out: out,
    }


def _mk_norm_stats(rng, R, C):
    x = jnp.asarray(rng.standard_normal((R, C)).astype(np.float32))

    def op_by_op(x):
        mu = ff.sum(x, axis=-1, block=128).to_f32() / C
        var = ff.sum((x - mu[..., None]) ** 2, axis=-1,
                     block=128).to_f32() / C
        return mu, var

    return {
        "args": (x,),
        "fused": jax.jit(lambda x: ff.norm_stats(x)),
        "unfused": op_by_op,
        "whole_jit": jax.jit(op_by_op),
        "resolved": ff.resolve_name("norm_stats", None, shape=(R, C)),
        "primary": lambda out: out[1],
    }


def _mk_axpy(rng, R, C):
    """Generic ff.fused showcase: z = a*x + y over FF tensors."""
    sh = (R, C)
    xh = rng.standard_normal(sh).astype(np.float32)
    yh = rng.standard_normal(sh).astype(np.float32)
    x = FF(jnp.asarray(xh),
           jnp.asarray((xh * 1e-8 * rng.standard_normal(sh)).astype(np.float32)))
    y = FF(jnp.asarray(yh),
           jnp.asarray((yh * 1e-8 * rng.standard_normal(sh)).astype(np.float32)))
    a = jnp.float32(1.618)

    chain = ff.fused(lambda a, x, y: a * x + y)

    def op_by_op(xh, xl, yh, yl):
        return ff.add(ff.mul(FF(xh, xl), a), FF(yh, yl)).astuple()

    return {
        "args": (x.hi, x.lo, y.hi, y.lo),
        "fused": jax.jit(
            lambda xh, xl, yh, yl: chain(a, FF(xh, xl), FF(yh, yl)).astuple()),
        "unfused": op_by_op,
        "whole_jit": jax.jit(op_by_op),
        "resolved": "fused(jnp)" if ff.backend() != "tpu" else "fused(pallas)",
        "primary": lambda out: out[0],
    }


CHAINS: Dict[str, Callable] = {
    "adamw": _mk_adamw,
    "softmax": _mk_softmax,
    "logsumexp": _mk_logsumexp,
    "rmsnorm_stats": _mk_rmsnorm_stats,
    "norm_stats": _mk_norm_stats,
    "axpy": _mk_axpy,
}


def run(shapes: Sequence[Tuple[int, int]] = ((256, 1024), (4096, 4096)),
        chains: Optional[Sequence[str]] = None,
        reps: int = 5, rounds: int = 9) -> List[Dict]:
    from repro.ff.tuning import time_interleaved

    rng = np.random.default_rng(0)
    rows: List[Dict] = []
    for R, C in shapes:
        for name in (chains or CHAINS):
            spec = CHAINS[name](rng, R, C)
            arms = ["fused", "unfused", "whole_jit"]
            res = time_interleaved([spec[a] for a in arms], spec["args"],
                                   reps, rounds=rounds,
                                   sample_target_s=0.05, rep_cap=25 * reps,
                                   min_reps=2)
            bad = [a for a, r in zip(arms, res) if r is None]
            if bad:
                raise RuntimeError(f"{name} arms failed to run: {bad}")
            t = {a: r[0] for a, r in zip(arms, res)}
            out_f = spec["primary"](spec["fused"](*spec["args"]))
            out_u = spec["primary"](spec["unfused"](*spec["args"]))
            out_w = spec["primary"](spec["whole_jit"](*spec["args"]))
            # the precision contract is same-compilation-mode: fused vs the
            # jitted op-by-op graph (eager-vs-jit already differs by ~1 ulp
            # through f32 div/sqrt chains for ANY program — recorded
            # separately as max_ulp_eager, informational)
            ulp = _ulp_diff(out_f, out_w)
            rows.append({
                "chain": name, "R": R, "C": C,
                "us_fused": t["fused"] * 1e6,
                "us_unfused": t["unfused"] * 1e6,
                "us_whole_jit": t["whole_jit"] * 1e6,
                "speedup": t["unfused"] / t["fused"],
                "resolved_impl": spec["resolved"],
                "max_ulp_diff": ulp,
                "max_ulp_eager": _ulp_diff(out_f, out_u),
                "ulp_tol": ULP_TOL[name],
                "backend": ff.backend(),
                "jax": jax.__version__,
            })
            if ulp > ULP_TOL[name]:
                raise AssertionError(
                    f"fused {name} diverged from the op-by-op path by "
                    f"{ulp:.1f} ulp (allowed {ULP_TOL[name]}) at "
                    f"({R}, {C}): precision regression")
    return rows


# the eager op-by-op arm's per-op dispatch overhead varies several-fold
# with machine load, so its speedup only carries a loose collapse gate;
# the fused/whole_jit ratio compares two JITTED arms and is stable enough
# for the same tight factor the matmul gate uses
SPEEDUP_COLLAPSE = 3.0
# sub-5ms rows are not timing-portable even between two idle runs of one
# box (measured 2-5x swings at (256, 1024)); they keep the accuracy gate
# but are exempt from both timing gates.  CI therefore gates timing at
# the memory-bound (4096, 4096) rows, which repeat within ~10%.
TIMING_GATE_FLOOR_US = 5000.0


def check_regression(rows: List[Dict], baseline,
                     factor: float = REGRESSION_FACTOR) -> List[str]:
    """Three gates per shared (chain, R, C) row, all machine-portable:

      1. accuracy: ``max_ulp_diff`` within the chain's documented
         tolerance (hard — precision is the product);
      2. fused vs whole-jit: ``us_fused/us_whole_jit`` must not grow by
         more than ``factor`` vs baseline (both arms jitted -> stable;
         catches 'the fused impl got slower than plain XLA fusion');
      3. fused vs op-by-op: the headline speedup must not collapse by
         more than ``SPEEDUP_COLLAPSE`` or below parity (the eager arm
         is load-sensitive, so this is deliberately loose).
    """
    if isinstance(baseline, str):
        with open(baseline) as f:
            baseline = json.load(f)
    now = {(r["chain"], r["R"], r["C"]): r for r in rows}
    then = {(r["chain"], r["R"], r["C"]): r
            for r in baseline.get("rows", [])}
    shared = sorted(set(now) & set(then))
    if not shared:
        return ["no overlapping (chain, R, C) rows between this run and "
                "the baseline: the regression gate compared nothing"]
    failures = []
    for key in shared:
        tag = f"{key[0]} {key[1]}x{key[2]}"
        r_now, r_then = now[key], then[key]
        if r_now["max_ulp_diff"] > r_now["ulp_tol"]:
            failures.append(
                f"{tag}: max_ulp_diff {r_now['max_ulp_diff']} > tol "
                f"{r_now['ulp_tol']}")
        if r_now["us_fused"] < TIMING_GATE_FLOOR_US:
            continue          # sub-5ms timings are noise, not signal
        w_now = r_now["us_fused"] / max(r_now["us_whole_jit"], 1e-9)
        w_then = r_then["us_fused"] / max(r_then["us_whole_jit"], 1e-9)
        if w_now > w_then * factor:
            failures.append(
                f"{tag}: fused/whole_jit ratio {w_now:.2f} vs baseline "
                f"{w_then:.2f} (allowed {factor}x growth)")
        s_now, s_then = r_now["speedup"], r_then["speedup"]
        if s_now * SPEEDUP_COLLAPSE < s_then or s_now < 1.0:
            failures.append(
                f"{tag}: fused speedup collapsed to {s_now:.2f}x "
                f"(baseline {s_then:.2f}x, allowed {SPEEDUP_COLLAPSE}x "
                f"decay, floor 1.0x)")
    return failures


def main(argv: Optional[Sequence[str]] = None,
         out_json: str = "BENCH_elementwise.json"):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--shapes", type=str, default="256x1024,4096x4096",
                    help="comma-separated RxC shapes")
    ap.add_argument("--chains", type=str, default="",
                    help="comma-separated subset of chains to bench")
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--rounds", type=int, default=9)
    ap.add_argument("--out", type=str, default=out_json)
    ap.add_argument("--check-regression", type=str, default="",
                    help="baseline BENCH json; exit 1 if speedups regressed")
    args = ap.parse_args([] if argv is None else argv)

    shapes = tuple(tuple(int(d) for d in s.split("x"))
                   for s in args.shapes.split(",") if s)
    chains = tuple(c for c in args.chains.split(",") if c) or None
    baseline = None
    if args.check_regression:
        with open(args.check_regression) as f:
            baseline = json.load(f)

    rows = run(shapes=shapes, chains=chains, reps=args.reps,
               rounds=args.rounds)

    print("elementwise: chain,RxC,us_fused,us_unfused,speedup,ulp,resolved")
    for r in rows:
        print(f"{r['chain']},{r['R']}x{r['C']},{r['us_fused']:.0f},"
              f"{r['us_unfused']:.0f},{r['speedup']:.2f}x,"
              f"{r['max_ulp_diff']:.1f},{r['resolved_impl']}")
    payload = {
        "bench": "elementwise",
        "backend": ff.backend(),
        "jax": jax.__version__,
        "shapes": [list(s) for s in shapes],
        "rows": rows,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out} (backend={payload['backend']})")

    if baseline is not None:
        failures = check_regression(rows, baseline)
        if failures:
            print("PERF/ACCURACY REGRESSION vs", args.check_regression)
            for f_ in failures:
                print(" ", f_)
            sys.exit(1)
        print(f"regression check vs {args.check_regression}: OK")
    return rows


if __name__ == "__main__":
    main(sys.argv[1:])
