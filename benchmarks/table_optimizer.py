"""Beyond-paper table: FF-master-weight optimizer — cost and the
stagnation experiment at production learning-rate scales.

Columns:
  adamw_f32 / adamw_ff   — us per step on a 1M-param pytree (overhead of
                           the Add22 weight update: paper Table 3's claim
                           'Add22 ~2x basic ops' predicts a small % of a
                           full AdamW step);
  stagnation_f32 / _ff   — relative weight drift after 2000 steps of
                           sub-ulp updates (f32 stalls at 0, FF tracks).
"""

from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.optim.adamw import AdamW


def _step_time(ff: bool, n=1 << 20, reps=20):
    params = {"w": jnp.ones((n,), jnp.float32)}
    g = {"w": jnp.full((n,), 1e-3, jnp.float32)}
    opt = AdamW(learning_rate=1e-4, ff=ff)
    state = opt.init(params)
    step = jax.jit(lambda p, s: opt.update(g, s, p))
    p, s = step(params, state)
    jax.block_until_ready(p["w"])
    t0 = time.perf_counter()
    for _ in range(reps):
        p, s = step(p, s)
    jax.block_until_ready(p["w"])
    return (time.perf_counter() - t0) / reps


def _stagnation(ff: bool, steps=2000):
    params = {"w": jnp.full((1024,), 1.0, jnp.float32)}
    g = {"w": jnp.full((1024,), 1.0, jnp.float32)}
    opt = AdamW(learning_rate=2e-9, b1=0.0, b2=0.0, eps=1e-30,
                weight_decay=0.0, ff=ff)
    state = opt.init(params)
    step = jax.jit(lambda p, s: opt.update(g, s, p))
    p, s = params, state
    for _ in range(steps):
        p, s = step(p, s)
    expected_drift = 2e-9 * steps
    if ff:
        total = (np.asarray(p["w"], np.float64)
                 + np.asarray(s.master_lo["w"], np.float64))
        got = float(np.mean(1.0 - total))
    else:
        got = float(np.mean(1.0 - np.asarray(p["w"], np.float64)))
    return got / expected_drift   # 1.0 = perfect tracking, 0.0 = stagnated


def main():
    print("optimizer: name,us_per_call,derived")
    t32 = _step_time(False)
    tff = _step_time(True)
    print(f"adamw_f32_1Mparam,{t32*1e6:.0f},baseline")
    print(f"adamw_ff_1Mparam,{tff*1e6:.0f},overhead={tff/t32:.2f}x")
    s32 = _stagnation(False)
    sff = _stagnation(True)
    print(f"stagnation_f32,0,tracked_frac={s32:.3f}")
    print(f"stagnation_ff,0,tracked_frac={sff:.3f}")


if __name__ == "__main__":
    main()
