"""Beyond-paper table: FF elementary functions vs hardware builtins vs f64.

The paper's companion study (Daumas, Da Graça & Defour) benchmarked GPU
built-in elementary functions and found them far less accurate than the
emulated arithmetic; this table reproduces that measurement for the
``ff.math`` tier on today's backends and prices the fix:

  arm ``ff``    — the compensated FF implementation (``impl="jnp"``:
                  argument reduction + FF polynomial kernels), jitted.
  arm ``f32``   — the hardware builtin (``impl="fast"``: one ``jnp.exp``
                  etc. on the rounded hi limb) — the baseline every FF
                  pipeline silently drops to without this subsystem.
  arm ``f64``   — the native-double tier (``impl="f64"``, CPU/GPU; on TPU
                  it degrades to the FF kernel and the row says so).

Per row: throughput (shared shuffled-interleave protocol,
``repro.ff.tuning.time_interleaved``), the measured worst relative error
of each arm vs an f64 oracle (as ``log2``), and the documented contract
bound.  The accuracy gate is hard — an ``ff`` arm missing its NUMERICS
contract fails the run, matching the acceptance criterion.  Emits
``BENCH_math.json``; ``--check-regression`` compares the ``ff``/``f32``
cost ratio against a committed baseline ratio-wise (machine-portable)
and re-asserts the accuracy contracts.

Modes:
  python -m benchmarks.table_math                       # default table
  python -m benchmarks.table_math --shape 512x512
  python -m benchmarks.table_math --check-regression BENCH_math.json
"""

from __future__ import annotations

import argparse
import json
import math
import os
import sys
from typing import Dict, List, Optional, Sequence, Tuple

_flags = os.environ.get("XLA_FLAGS", "")
if "--xla_cpu_max_isa" not in _flags:
    os.environ["XLA_FLAGS"] = ("--xla_cpu_max_isa=SSE4_2 " + _flags).strip()

import numpy as np
import jax
import jax.numpy as jnp

import repro.ff as ff
from repro.core.ff import FF

REGRESSION_FACTOR = 1.5
# sub-ms rows are dispatch/launch noise, not kernel signal (same floor
# philosophy as table_elementwise, scaled to elementwise-op cost)
TIMING_GATE_FLOOR_US = 2000.0

_ERF64 = np.vectorize(math.erf)

# (sampler low/high on the f64 input, oracle, documented ff contract)
FUNCS: Dict[str, Tuple[Tuple[float, float], object, float]] = {
    "exp": ((-55.0, 80.0), np.exp, 2.0**-42),
    "expm1": ((-20.0, 20.0), np.expm1, 2.0**-41),
    "log": ((math.exp(-50.0), math.exp(50.0)), np.log, 2.0**-42),
    "log1p": ((-0.29, 10.0), np.log1p, 2.0**-43),
    "tanh": ((-20.0, 20.0), np.tanh, 2.0**-41),
    "sigmoid": ((-30.0, 30.0), lambda t: 1 / (1 + np.exp(-t)), 2.0**-42),
    "erf": ((-6.0, 6.0), _ERF64, 2.0**-42),
    "gelu": ((-1.0, 20.0), lambda t: 0.5 * t * (1 + _ERF64(t / np.sqrt(2))),
             2.0**-42),
    "silu": ((-30.0, 30.0), lambda t: t / (1 + np.exp(-t)), 2.0**-42),
}


def _ff_operand(rng, shape, lo_, hi_):
    x64 = rng.uniform(lo_, hi_, shape)
    h = np.float32(x64)
    l = np.float32(x64 - np.float64(h))
    return FF(jnp.asarray(h), jnp.asarray(l)), np.float64(h) + np.float64(l)


def _measured_err(fn: str, out: FF, xin, oracle) -> float:
    got = np.float64(np.asarray(out.hi)) + np.float64(np.asarray(out.lo))
    want = oracle(xin)
    ok = np.isfinite(want) & (np.abs(want) > 1e-300)
    err = np.abs(got[ok] - want[ok]) / np.abs(want[ok])
    return float(err.max()) if err.size else 0.0


def run(shape: Tuple[int, int] = (1024, 1024),
        funcs: Optional[Sequence[str]] = None,
        reps: int = 5, rounds: int = 7) -> List[Dict]:
    from repro.ff.tuning import time_interleaved

    rng = np.random.default_rng(0)
    R, C = shape
    rows: List[Dict] = []
    for name in (funcs or FUNCS):
        (lo_, hi_), oracle, bound = FUNCS[name]
        x, xin = _ff_operand(rng, (R, C), lo_, hi_)
        op = getattr(ff, name)
        arms = {
            "ff": jax.jit(lambda a, op=op: op(a, impl="jnp")),
            "f32": jax.jit(lambda a, op=op: op(a, impl="fast")),
            "f64": jax.jit(lambda a, op=op: op(a, impl="f64")),
        }
        res = time_interleaved(list(arms.values()), (x,), reps,
                               rounds=rounds, sample_target_s=0.05)
        bad = [a for a, r in zip(arms, res) if r is None]
        if bad:
            raise RuntimeError(f"{name} arms failed to run: {bad}")
        t = {a: r[0] for a, r in zip(arms, res)}
        errs = {a: _measured_err(name, arms[a](x), xin, oracle)
                for a in arms}
        row = {
            "fn": name, "R": R, "C": C,
            "us_ff": t["ff"] * 1e6, "us_f32": t["f32"] * 1e6,
            "us_f64": t["f64"] * 1e6,
            "cost_ratio": t["ff"] / t["f32"],
            # informational only — check_regression gates on the
            # median-normalized us_ff (the f32/f64 arms are few-ms
            # programs whose wall-clock swings 1.5x+ under load)
            "ratio_vs_f64": t["ff"] / t["f64"],
            "log2_err_ff": math.log2(max(errs["ff"], 1e-300)),
            "log2_err_f32": math.log2(max(errs["f32"], 1e-300)),
            "log2_err_f64": math.log2(max(errs["f64"], 1e-300)),
            "log2_bound": math.log2(bound),
            "backend": ff.backend(),
            "jax": jax.__version__,
        }
        rows.append(row)
        # hard accuracy gates: the documented contract is the product
        if errs["ff"] > bound:
            raise AssertionError(
                f"ff.{name}: measured 2^{row['log2_err_ff']:.1f} exceeds "
                f"the documented contract 2^{row['log2_bound']:.1f}")
        if errs["f32"] < errs["ff"]:
            raise AssertionError(
                f"ff.{name}: the f32 builtin out-measured the FF impl — "
                f"the subsystem's premise is broken")
    return rows


def check_regression(rows: List[Dict], baseline,
                     factor: float = REGRESSION_FACTOR) -> List[str]:
    """Per shared (fn, R, C) row: the accuracy contract (hard) and the
    function's MEDIAN-NORMALIZED ff cost (``us_ff`` divided by the median
    ``us_ff`` over the shared rows), which must not grow by more than
    ``factor`` vs the committed baseline.  Only the heavyweight ff arms
    enter the ratio — they are the one timing signal stable across both
    load and machines (the f32/f64 arms are few-ms programs whose
    wall-clock swings 1.5x+ under contention; measured while building
    this gate).  Catches "one kernel got relatively slower" — the
    realistic regression for an elementwise family.  Sub-2ms rows skip
    the timing gate (noise floor)."""
    if isinstance(baseline, str):
        with open(baseline) as f:
            baseline = json.load(f)
    now = {(r["fn"], r["R"], r["C"]): r for r in rows}
    then = {(r["fn"], r["R"], r["C"]): r for r in baseline.get("rows", [])}
    shared = sorted(set(now) & set(then))
    if not shared:
        return ["no overlapping (fn, R, C) rows between this run and the "
                "baseline: the regression gate compared nothing"]
    import statistics
    med_now = statistics.median(now[k]["us_ff"] for k in shared)
    med_then = statistics.median(then[k]["us_ff"] for k in shared)
    failures = []
    for key in shared:
        r_now, r_then = now[key], then[key]
        tag = f"{key[0]} {key[1]}x{key[2]}"
        if r_now["log2_err_ff"] > r_now["log2_bound"]:
            failures.append(
                f"{tag}: accuracy 2^{r_now['log2_err_ff']:.1f} > contract "
                f"2^{r_now['log2_bound']:.1f}")
        if r_now["us_ff"] < TIMING_GATE_FLOOR_US:
            continue
        rel_now = r_now["us_ff"] / med_now
        rel_then = r_then["us_ff"] / med_then
        if rel_now > rel_then * factor:
            failures.append(
                f"{tag}: median-normalized ff cost {rel_now:.2f} vs "
                f"baseline {rel_then:.2f} (allowed {factor}x growth)")
    return failures


def main(argv: Optional[Sequence[str]] = None,
         out_json: str = "BENCH_math.json"):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--shape", type=str, default="1024x1024")
    ap.add_argument("--funcs", type=str, default="",
                    help="comma-separated subset of functions")
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--rounds", type=int, default=7)
    ap.add_argument("--out", type=str, default=out_json)
    ap.add_argument("--check-regression", type=str, default="",
                    help="baseline BENCH json; exit 1 on ratio/contract "
                         "regression")
    args = ap.parse_args([] if argv is None else argv)

    R, C = (int(d) for d in args.shape.split("x"))
    funcs = tuple(f for f in args.funcs.split(",") if f) or None
    rows = run(shape=(R, C), funcs=funcs, reps=args.reps,
               rounds=args.rounds)

    print("math: fn,us_ff,us_f32,us_f64,ratio,err_ff,err_f32,err_f64,bound")
    for r in rows:
        print(f"{r['fn']},{r['us_ff']:.0f},{r['us_f32']:.0f},"
              f"{r['us_f64']:.0f},{r['cost_ratio']:.1f}x,"
              f"2^{r['log2_err_ff']:.1f},2^{r['log2_err_f32']:.1f},"
              f"2^{r['log2_err_f64']:.1f},2^{r['log2_bound']:.0f}")
    payload = {
        "bench": "math",
        "backend": ff.backend(),
        "jax": jax.__version__,
        "shape": [R, C],
        "rows": rows,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out} (backend={payload['backend']})")

    if args.check_regression:
        failures = check_regression(rows, args.check_regression)
        if failures:
            print("PERF/ACCURACY REGRESSION vs", args.check_regression)
            for f_ in failures:
                print(" ", f_)
            sys.exit(1)
        print(f"regression check vs {args.check_regression}: OK")
    return rows


if __name__ == "__main__":
    main(sys.argv[1:])
