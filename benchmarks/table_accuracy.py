"""Paper Table 5 analogue: measured accuracy of the FF operators.

The paper ran 2^24 random vectors against MPFR and reported max error as
log2: Add12 -48.0 (bug: should be exact), Mul12 exact, Add22 -33.7
(their hardware bug), Mul22 -45.0.

Here f64 is an *exact* oracle (every EFT result fits in 53 bits), so we
report both the paper-style sampled max log2-relative-error AND the
exactness checks the 2006 hardware failed.  Default 2^22 samples per op
(2^24 with --full) in 2^20 chunks.
"""

from __future__ import annotations

import argparse
from typing import Dict

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import FF, add12, add22, add22_accurate, mul12, mul22, div22

CHUNK = 1 << 20


def _rand(rng, n, lo=-8, hi=8):
    return (rng.standard_normal(n) * 10.0 ** rng.uniform(lo, hi, n)
            ).astype(np.float32)


def measure(n_total: int = 1 << 22) -> Dict[str, float]:
    rng = np.random.default_rng(2006)
    worst = {"Add12": 0.0, "Mul12": 0.0, "Add22": 0.0, "Add22_acc": 0.0,
             "Mul22": 0.0, "Div22": 0.0}
    add12_exact = mul12_exact = True
    j_add12 = jax.jit(lambda a, b: add12(a, b).astuple())
    j_mul12 = jax.jit(lambda a, b: mul12(a, b).astuple())
    j_add22 = jax.jit(lambda ah, al, bh, bl: add22(FF(ah, al), FF(bh, bl)).astuple())
    j_add22a = jax.jit(lambda ah, al, bh, bl: add22_accurate(FF(ah, al), FF(bh, bl)).astuple())
    j_mul22 = jax.jit(lambda ah, al, bh, bl: mul22(FF(ah, al), FF(bh, bl)).astuple())
    j_div22 = jax.jit(lambda ah, al, bh, bl: div22(FF(ah, al), FF(bh, bl)).astuple())

    for _ in range(max(1, n_total // CHUNK)):
        a, b = _rand(rng, CHUNK), _rand(rng, CHUNK)
        a64, b64 = a.astype(np.float64), b.astype(np.float64)

        s, r = j_add12(a, b)
        got = np.asarray(s, np.float64) + np.asarray(r, np.float64)
        add12_exact &= bool(np.array_equal(got, a64 + b64))

        prod = a64 * b64
        ok = (np.abs(prod) < 1e25) & (np.abs(prod) > 1e-25)
        x, y = j_mul12(a, b)
        got = np.asarray(x, np.float64) + np.asarray(y, np.float64)
        mul12_exact &= bool(np.array_equal(got[ok], prod[ok]))

        # FF operands
        va = a64 * (1 + rng.uniform(-1e-9, 1e-9, CHUNK))
        vb = b64 * (1 + rng.uniform(-1e-9, 1e-9, CHUNK))
        fa, fb = FF.from_f64(va), FF.from_f64(vb)
        va, vb = fa.to_f64(), fb.to_f64()
        args = (fa.hi, fa.lo, fb.hi, fb.lo)

        for name, fn, exact in (
            ("Add22", j_add22, va + vb),
            ("Add22_acc", j_add22a, va + vb),
            ("Mul22", j_mul22, va * vb),
            ("Div22", j_div22, va / vb),
        ):
            h, l = fn(*args)
            got = np.asarray(h, np.float64) + np.asarray(l, np.float64)
            denom = np.maximum(np.abs(exact), 1e-300)
            rel = np.abs(got - exact) / denom
            if name == "Add22":
                # paper bound is vs max(2^-24|al+bl|, 2^-44|sum|): report raw
                pass
            worst[name] = max(worst[name], float(rel.max()))

    out = {
        "Add12_exact": add12_exact,
        "Mul12_exact": mul12_exact,
    }
    for k in ("Add22", "Add22_acc", "Mul22", "Div22"):
        out[k + "_log2err"] = float(np.log2(max(worst[k], 2.0**-60)))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="2^24 samples (paper)")
    args, _ = ap.parse_known_args()
    res = measure(1 << 24 if args.full else 1 << 22)
    print("table5_accuracy: name,value,paper")
    print(f"Add12_exact,{res['Add12_exact']},paper=-48.0(hw bug; theory=exact)")
    print(f"Mul12_exact,{res['Mul12_exact']},paper=exact")
    print(f"Add22_log2_maxrelerr,{res['Add22_log2err']:.1f},paper=-33.7(hw bug)")
    print(f"Add22_accurate_log2_maxrelerr,{res['Add22_acc_log2err']:.1f},paper=n/a")
    print(f"Mul22_log2_maxrelerr,{res['Mul22_log2err']:.1f},paper=-45.0")
    print(f"Div22_log2_maxrelerr,{res['Div22_log2err']:.1f},paper=n/a")


if __name__ == "__main__":
    main()
