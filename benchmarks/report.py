"""Assemble EXPERIMENTS.md sections from dry-run/roofline/perf artifacts.

Usage: PYTHONPATH=src python -m benchmarks.report > EXPERIMENTS_generated.md
(The checked-in EXPERIMENTS.md embeds this output plus analysis.)

Every section degrades gracefully when its artifact is absent (a fresh
checkout has none) — missing inputs print a one-line note instead of
crashing, so the report always renders whatever subset of artifacts the
CI run produced.  The leading section aggregates all five BENCH_*.json
families (ffmatmul, elementwise, math, serving, distributed) into one
headline table.
"""

from __future__ import annotations

import glob
import json
import os

#: the five benchmark families ``benchmarks/run.py`` and CI produce
BENCH_FAMILIES = ("ffmatmul", "elementwise", "math", "serving",
                  "distributed")


def _fmt_b(x):
    return f"{x/2**30:.2f}"


# --------------------------------------------------------------------------
# cross-family benchmark summary (one row per BENCH_*.json)
# --------------------------------------------------------------------------

def _headline(family, payload):
    """One-line headline metric string for a bench family's payload."""
    rows = payload.get("rows", [])
    if family == "ffmatmul":
        err = max((r.get("log2_err", -300) for r in rows), default=None)
        fast = min((r for r in rows if r.get("us_median")),
                   key=lambda r: r["us_median"], default=None)
        bits = []
        if fast:
            bits.append(f"fastest {fast['path']} K={fast['K']} "
                        f"{fast['us_median']:.0f}us")
        if err is not None:
            bits.append(f"worst err 2^{err:.1f}")
        return "; ".join(bits)
    if family == "elementwise":
        sp = max((r.get("speedup", 0.0) for r in rows), default=None)
        ulp = max((r.get("max_ulp_diff", 0) for r in rows), default=None)
        return (f"best fusion speedup {sp:.2f}x; "
                f"max fused-vs-unfused ulp {ulp}" if rows else "")
    if family == "math":
        worst = max(rows, key=lambda r: r.get("log2_err_ff", -300),
                    default=None)
        if not worst:
            return ""
        return (f"worst fn {worst['fn']} err 2^{worst['log2_err_ff']:.1f} "
                f"(bound 2^{worst.get('log2_bound', 0):.1f})")
    if family == "serving":
        eng = [r for r in rows if r.get("arm") == "engine"]
        best = max(eng, key=lambda r: r.get("tokens_per_s", 0.0),
                   default=None)
        bits = []
        if best:
            bits.append(f"engine B={best['batch']} "
                        f"{best['tokens_per_s']:.0f} tok/s "
                        f"({best['speedup_vs_greedy']:.1f}x greedy)")
        for key, label in (("guard_overhead", "guard"),
                           ("snapshot_overhead", "snapshot"),
                           ("obs_overhead", "obs")):
            r = next((r for r in rows if key in r), None)
            if r:
                bits.append(f"{label} {r[key]:.3f}x")
        return "; ".join(bits)
    if family == "distributed":
        best = max(rows, key=lambda r: r.get("scaled_speedup", 0.0),
                   default=None)
        if not best:
            return ""
        return (f"best scaled speedup {best['scaled_speedup']:.2f}x "
                f"({best.get('op', '?')} d={best.get('devices', '?')})")
    return ""


def bench_summary(artifacts="."):
    """Aggregate every ``BENCH_<family>.json`` under ``artifacts`` into one
    markdown table: family, backend, row count, headline metric.  Families
    whose artifact is missing get an explicit `missing` row rather than
    being silently dropped."""
    print("### Benchmark summary (all families)\n")
    print("| family | backend | jax | rows | headline |")
    print("|---|---|---|---|---|")
    found = 0
    for family in BENCH_FAMILIES:
        path = os.path.join(artifacts, f"BENCH_{family}.json")
        if not os.path.exists(path):
            print(f"| {family} | — | — | — | missing ({path}) |")
            continue
        try:
            payload = json.load(open(path))
        except (OSError, json.JSONDecodeError) as e:
            print(f"| {family} | — | — | — | unreadable: {e} |")
            continue
        found += 1
        meta = payload.get("meta", payload)
        backend = meta.get("backend", "?")
        jax_ver = meta.get("jax", "?")
        rows = payload.get("rows", [])
        print(f"| {family} | {backend} | {jax_ver} | {len(rows)} | "
              f"{_headline(family, payload) or '—'} |")
    print(f"\n{found}/{len(BENCH_FAMILIES)} families present.\n")


def dryrun_table(artifacts="artifacts/dryrun_final"):
    rows = []
    for path in sorted(glob.glob(os.path.join(artifacts, "*.json"))):
        rows.append(json.load(open(path)))
    if not rows:
        print(f"### Dry-run matrix\n\n(no artifacts under {artifacts})\n")
        return
    print("### Dry-run matrix (every arch x shape x mesh; lower+compile)\n")
    print("| arch | shape | mesh | status | args GiB/dev | temp GiB/dev | "
          "HLO flops/dev | HBM bytes/dev | collective bytes/dev | compile s |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    n_ok = n_skip = n_fail = 0
    for r in rows:
        if r["status"] == "ok":
            n_ok += 1
            m = r["memory"]
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
                  f"{_fmt_b(m['argument_size_in_bytes'])} | "
                  f"{_fmt_b(m['temp_size_in_bytes'])} | "
                  f"{r['cost']['flops']:.2e} | {r['cost']['bytes']:.2e} | "
                  f"{r['collectives']['total']:.2e} | "
                  f"{r['compile_seconds']:.0f} |")
        elif r["status"] == "skipped":
            n_skip += 1
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                  f"SKIP ({r['reason'].split(':')[0]}) | | | | | | |")
        else:
            n_fail += 1
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | **FAILED** "
                  f"| | | | | | |")
    print(f"\ncells: {n_ok} compiled ok, {n_skip} skipped "
          f"(documented rule), {n_fail} failed.\n")


def roofline_table(path="artifacts/roofline_final.json"):
    if not os.path.exists(path):
        print(f"### Roofline\n\n(no artifact at {path})\n")
        return
    rows = json.load(open(path))
    print("### Roofline (single-pod 16x16 = 256 chips; "
          "197 TF/s bf16, 819 GB/s HBM, 50 GB/s ICI per chip)\n")
    print("| arch | shape | compute s | memory s | collective s | "
          "bottleneck | roofline frac | MODEL/HLO |")
    print("|---|---|---|---|---|---|---|---|")
    for r in rows:
        print(f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
              f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
              f"{r['bottleneck']} | {r['roofline_fraction']:.3f} | "
              f"{r['useful_ratio']:.2f} |")
    print()


def perf_log(pattern="artifacts/perf_iter*.json"):
    print("### Perf iteration log\n")
    if not glob.glob(pattern):
        print(f"(no artifacts matching {pattern})\n")
        return
    for path in sorted(glob.glob(pattern)):
        it = json.load(open(path))
        print(f"**Iteration {it['iteration']}** — {it['cell']}")
        print(f"- hypothesis: {it['hypothesis']}")
        if "results" in it:
            for k, v in it["results"].items():
                print(f"  - {k}: {json.dumps(v, default=float)}")
        print(f"- verdict: {it['verdict']}")
        print(f"- lesson: {it['lesson']}\n")


def main():
    bench_summary()
    dryrun_table()
    roofline_table()
    perf_log()


if __name__ == "__main__":
    main()
