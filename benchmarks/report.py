"""Assemble EXPERIMENTS.md sections from dry-run/roofline/perf artifacts.

Usage: PYTHONPATH=src python -m benchmarks.report > EXPERIMENTS_generated.md
(The checked-in EXPERIMENTS.md embeds this output plus analysis.)
"""

from __future__ import annotations

import glob
import json
import os


def _fmt_b(x):
    return f"{x/2**30:.2f}"


def dryrun_table(artifacts="artifacts/dryrun_final"):
    rows = []
    for path in sorted(glob.glob(os.path.join(artifacts, "*.json"))):
        rows.append(json.load(open(path)))
    print("### Dry-run matrix (every arch x shape x mesh; lower+compile)\n")
    print("| arch | shape | mesh | status | args GiB/dev | temp GiB/dev | "
          "HLO flops/dev | HBM bytes/dev | collective bytes/dev | compile s |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    n_ok = n_skip = n_fail = 0
    for r in rows:
        if r["status"] == "ok":
            n_ok += 1
            m = r["memory"]
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
                  f"{_fmt_b(m['argument_size_in_bytes'])} | "
                  f"{_fmt_b(m['temp_size_in_bytes'])} | "
                  f"{r['cost']['flops']:.2e} | {r['cost']['bytes']:.2e} | "
                  f"{r['collectives']['total']:.2e} | "
                  f"{r['compile_seconds']:.0f} |")
        elif r["status"] == "skipped":
            n_skip += 1
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                  f"SKIP ({r['reason'].split(':')[0]}) | | | | | | |")
        else:
            n_fail += 1
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | **FAILED** "
                  f"| | | | | | |")
    print(f"\ncells: {n_ok} compiled ok, {n_skip} skipped "
          f"(documented rule), {n_fail} failed.\n")


def roofline_table(path="artifacts/roofline_final.json"):
    rows = json.load(open(path))
    print("### Roofline (single-pod 16x16 = 256 chips; "
          "197 TF/s bf16, 819 GB/s HBM, 50 GB/s ICI per chip)\n")
    print("| arch | shape | compute s | memory s | collective s | "
          "bottleneck | roofline frac | MODEL/HLO |")
    print("|---|---|---|---|---|---|---|---|")
    for r in rows:
        print(f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
              f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
              f"{r['bottleneck']} | {r['roofline_fraction']:.3f} | "
              f"{r['useful_ratio']:.2f} |")
    print()


def perf_log(pattern="artifacts/perf_iter*.json"):
    print("### Perf iteration log\n")
    for path in sorted(glob.glob(pattern)):
        it = json.load(open(path))
        print(f"**Iteration {it['iteration']}** — {it['cell']}")
        print(f"- hypothesis: {it['hypothesis']}")
        if "results" in it:
            for k, v in it["results"].items():
                print(f"  - {k}: {json.dumps(v, default=float)}")
        print(f"- verdict: {it['verdict']}")
        print(f"- lesson: {it['lesson']}\n")


def main():
    dryrun_table()
    roofline_table()
    perf_log()


if __name__ == "__main__":
    main()
